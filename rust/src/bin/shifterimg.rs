//! `shifterimg` — the Image Gateway CLI (§III.B).
//!
//! ```text
//! shifterimg [--system=daint] pull docker:ubuntu:xenial
//! shifterimg [--system=daint] images
//! shifterimg [--system=daint] lookup docker:ubuntu:xenial
//! shifterimg [--system=daint] [--shards=4] cluster-status
//! shifterimg [--system=daint] [--shards=4] [--nodes=64] [--gpus=1] \
//!     [--mpi] [--hetero] launch <ref> [cmd...]
//! ```
//!
//! `cluster-status` drives the distributed fabric (DESIGN.md S18): it
//! pulls the full registry catalog through a sharded gateway cluster and
//! prints the per-shard queue/image state plus the content-addressed
//! store's dedup accounting.
//!
//! `launch` drives the full cluster-scale job orchestrator (DESIGN.md
//! S19): WLM allocation, one coalesced pull, per-node stage execution on
//! a worker pool, and the percentile launch report. `--hetero` splits the
//! node range into a Piz Daint partition and a Linux Cluster partition
//! (different GPU generations, driver versions and host MPIs).

use shifter_rs::distrib::DistributionFabric;
use shifter_rs::launch::{JobSpec, LaunchCluster, LaunchScheduler};
use shifter_rs::metrics::Table;
use shifter_rs::util::cli::CliSpec;
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn usage() -> ! {
    eprintln!(
        "usage: shifterimg [--system=laptop|cluster|daint] [--shards=N] \
         [--nodes=N] [--gpus=N] [--mpi] [--hetero] \
         <pull <ref> | images | lookup <ref> | cluster-status | \
         launch <ref> [cmd...]>"
    );
    std::process::exit(2);
}

fn main() {
    let spec = CliSpec::new(
        &[
            ("system", true),
            ("shards", true),
            ("nodes", true),
            ("gpus", true),
            ("mpi", false),
            ("hetero", false),
        ],
        // stop option parsing at the subcommand, so a containerized
        // command like `launch <ref> ls --color` keeps its own flags
        true,
    );
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifterimg: {e}");
            usage();
        }
    };
    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        _ => usage(),
    };
    let pfs = profile
        .pfs
        .clone()
        .unwrap_or_else(shifter_rs::pfs::LustreFs::piz_daint);
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(pfs.clone());

    match parsed.positionals.as_slice() {
        [cmd, reference] if cmd == "pull" => {
            match gateway.pull(&registry, reference) {
                Ok(rep) => {
                    println!(
                        "{}: pulled in {:.1}s (download {:.1}s, expand {:.1}s, \
                         squashfs {:.1}s, store {:.1}s){}",
                        rep.reference,
                        rep.total_secs(),
                        rep.download_secs,
                        rep.expand_secs,
                        rep.convert_secs,
                        rep.store_secs,
                        if rep.cached { " [cached]" } else { "" }
                    );
                }
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "images" => {
            // a fresh gateway has nothing pulled; list the registry too so
            // the demo binary is useful on its own
            println!("registry ({}):", registry.len());
            for r in registry.list() {
                println!("  {r}");
            }
            println!("gateway ({}):", gateway.list().len());
            for r in gateway.list() {
                println!("  {r}");
            }
        }
        [cmd, reference] if cmd == "lookup" => {
            match gateway
                .pull(&registry, reference)
                .and_then(|_| gateway.lookup(reference).map(|g| g.pfs_path.clone()))
            {
                Ok(path) => println!("{reference} -> {path}"),
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "cluster-status" => {
            let shards = parse_shards(&parsed);
            let mut fabric = DistributionFabric::new(shards, pfs);
            // drive the whole catalog through the cluster, as a site's
            // nightly sync would
            for reference in registry.list() {
                if let Err(e) = fabric.request(&registry, &reference, "admin") {
                    eprintln!("shifterimg: {reference}: {e}");
                }
            }
            fabric.tick(&registry, 1e9);

            let mut table = Table::new(
                &format!("cluster status ({shards} shards)"),
                &[
                    "shard", "backlog", "ready", "failed", "images",
                    "max-wait", "active",
                ],
            );
            for s in fabric.cluster().cluster_status() {
                table.row(&[
                    s.shard.to_string(),
                    s.backlog.to_string(),
                    s.ready.to_string(),
                    s.failed.to_string(),
                    s.images.to_string(),
                    format!("{:.1}s", s.max_queue_wait_secs),
                    s.active.unwrap_or_else(|| "-".to_string()),
                ]);
            }
            print!("{}", table.render());

            let cas = fabric.cluster().cas();
            println!(
                "storm drained in {:.1}s (makespan across shards)",
                fabric.cluster().makespan_secs()
            );
            if let Some(wait) = fabric.queue_wait_stats() {
                println!(
                    "queue wait across {} jobs: p50 {:.1}s, p95 {:.1}s, \
                     p99 {:.1}s, worst {:.1}s",
                    wait.n, wait.p50, wait.p95, wait.p99, wait.worst
                );
            }
            println!(
                "cas: {} blobs, {:.1} MB stored / {:.1} MB logical \
                 (dedup {:.2}x, {:.1} MB saved)",
                cas.blob_count(),
                cas.stored_bytes() as f64 / 1e6,
                cas.logical_bytes() as f64 / 1e6,
                cas.dedup_ratio(),
                cas.saved_bytes() as f64 / 1e6,
            );
        }
        [cmd, rest @ ..] if cmd == "launch" && !rest.is_empty() => {
            let reference = &rest[0];
            let command: Vec<&str> = if rest.len() > 1 {
                rest[1..].iter().map(|s| s.as_str()).collect()
            } else {
                vec!["true"]
            };
            let shards = parse_shards(&parsed);
            let nodes: u32 = match parsed.get("nodes").unwrap_or("64").parse() {
                Ok(n) if n >= 1 => n,
                _ => {
                    eprintln!("shifterimg: --nodes must be a positive integer");
                    usage();
                }
            };
            let gpus: u32 = match parsed.get("gpus").unwrap_or("0").parse() {
                Ok(n) => n,
                _ => {
                    eprintln!("shifterimg: --gpus must be an integer");
                    usage();
                }
            };
            let cluster = if parsed.has("hetero") {
                if nodes < 2 {
                    eprintln!("shifterimg: --hetero needs --nodes >= 2");
                    usage();
                }
                LaunchCluster::daint_linux_split(nodes)
            } else {
                LaunchCluster::homogeneous(&profile, nodes)
            };
            let mut fabric = DistributionFabric::new(shards, pfs);
            let mut job = JobSpec::new(reference, &command, nodes);
            if gpus > 0 {
                job = job.with_gpus(gpus);
            }
            if parsed.has("mpi") {
                job = job.with_mpi();
            }
            let scheduler = LaunchScheduler::new(&cluster, &registry);
            match scheduler.launch(&mut fabric, &job) {
                Ok(report) => {
                    print!("{}", report.render());
                    if report.failed() > 0 {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        _ => usage(),
    }
}

fn parse_shards(parsed: &shifter_rs::util::cli::ParsedArgs) -> usize {
    match parsed.get("shards").unwrap_or("4").parse() {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("shifterimg: --shards must be a positive integer");
            usage();
        }
    }
}
