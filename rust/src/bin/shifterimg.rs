//! `shifterimg` — the Image Gateway CLI (§III.B).
//!
//! ```text
//! shifterimg [--system=daint] pull docker:ubuntu:xenial
//! shifterimg [--system=daint] images
//! shifterimg [--system=daint] lookup docker:ubuntu:xenial
//! shifterimg [--system=daint] [--shards=4] cluster-status
//! shifterimg [--system=daint] [--shards=4] [--nodes=64] [--gpus=1] \
//!     [--mpi] [--hetero] launch <ref> [cmd...]
//! shifterimg [--system=daint] [--shards=4] [--nodes=256] [--hetero] \
//!     [--tenants=8] [--jobs=64] [--arrival-rate=2.4] [--duration=S] \
//!     [--policy=fair|fifo] [--seed=N] storm
//! ```
//!
//! `cluster-status` drives the distributed fabric (DESIGN.md S18): it
//! pulls the full registry catalog through a sharded gateway cluster and
//! prints the per-shard queue/image state plus the content-addressed
//! store's dedup accounting.
//!
//! `launch` drives the full cluster-scale job orchestrator (DESIGN.md
//! S19): WLM allocation, one coalesced pull, per-node stage execution on
//! a worker pool, and the percentile launch report. `--hetero` splits the
//! node range into a Piz Daint partition and a Linux Cluster partition
//! (different GPU generations, driver versions and host MPIs).
//!
//! `storm` drives the multi-tenant traffic simulator (DESIGN.md S20): a
//! Poisson stream of competing GPU/MPI/CPU jobs from `--tenants`
//! simulated users, scheduled with fair-share + conservative backfill
//! (`--policy=fair`, the default) or strict FIFO (`--policy=fifo`), over
//! one shared distribution fabric. Prints the per-tenant queue-wait and
//! stretch percentiles plus the gateway interference summary.

use shifter_rs::distrib::DistributionFabric;
use shifter_rs::launch::{JobSpec, LaunchCluster, LaunchScheduler};
use shifter_rs::metrics::Table;
use shifter_rs::tenancy::{FairShareScheduler, SchedulingPolicy, TrafficModel};
use shifter_rs::util::cli::CliSpec;
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn usage() -> ! {
    eprintln!(
        "usage: shifterimg [options] <subcommand>\n\
         \n\
         subcommands:\n\
         \x20 pull <ref>            pull an image through the gateway\n\
         \x20 images                list registry and gateway images\n\
         \x20 lookup <ref>          pull (if needed) and print the PFS path\n\
         \x20 cluster-status        drive the catalog through the sharded\n\
         \x20                       fabric and print per-shard state\n\
         \x20 launch <ref> [cmd..]  one cluster-scale containerized job\n\
         \x20 storm                 multi-tenant job-storm simulation\n\
         \n\
         common options:\n\
         \x20 --system=laptop|cluster|daint   host profile (default daint)\n\
         \x20 --shards=N                      gateway shards (default 4)\n\
         \x20 --nodes=N                       cluster width (launch: 64,\n\
         \x20                                 storm: 256)\n\
         \x20 --hetero                        split nodes into Piz Daint +\n\
         \x20                                 Linux Cluster partitions\n\
         \n\
         launch options:\n\
         \x20 --gpus=N              request --gres=gpu:N per node\n\
         \x20 --mpi                 activate the MPI ABI swap\n\
         \n\
         storm options:\n\
         \x20 --tenants=N           simulated tenants (default 8)\n\
         \x20 --jobs=N              jobs to synthesize (default 64)\n\
         \x20 --arrival-rate=R      aggregate arrivals per minute (2.4)\n\
         \x20 --duration=SECS       stop generating arrivals after SECS\n\
         \x20 --policy=fair|fifo    queue policy (default fair)\n\
         \x20 --seed=N              traffic PRNG seed (default 7)"
    );
    std::process::exit(2);
}

fn main() {
    let spec = CliSpec::new(
        &[
            ("system", true),
            ("shards", true),
            ("nodes", true),
            ("gpus", true),
            ("mpi", false),
            ("hetero", false),
            ("tenants", true),
            ("jobs", true),
            ("arrival-rate", true),
            ("duration", true),
            ("policy", true),
            ("seed", true),
        ],
        // stop option parsing at the subcommand, so a containerized
        // command like `launch <ref> ls --color` keeps its own flags
        true,
    );
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifterimg: {e}");
            usage();
        }
    };
    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        _ => usage(),
    };
    let pfs = profile
        .pfs
        .clone()
        .unwrap_or_else(shifter_rs::pfs::LustreFs::piz_daint);
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(pfs.clone());

    match parsed.positionals.as_slice() {
        [cmd, reference] if cmd == "pull" => {
            match gateway.pull(&registry, reference) {
                Ok(rep) => {
                    println!(
                        "{}: pulled in {:.1}s (download {:.1}s, expand {:.1}s, \
                         squashfs {:.1}s, store {:.1}s){}",
                        rep.reference,
                        rep.total_secs(),
                        rep.download_secs,
                        rep.expand_secs,
                        rep.convert_secs,
                        rep.store_secs,
                        if rep.cached { " [cached]" } else { "" }
                    );
                }
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "images" => {
            // a fresh gateway has nothing pulled; list the registry too so
            // the demo binary is useful on its own
            println!("registry ({}):", registry.len());
            for r in registry.list() {
                println!("  {r}");
            }
            println!("gateway ({}):", gateway.list().len());
            for r in gateway.list() {
                println!("  {r}");
            }
        }
        [cmd, reference] if cmd == "lookup" => {
            match gateway
                .pull(&registry, reference)
                .and_then(|_| gateway.lookup(reference).map(|g| g.pfs_path.clone()))
            {
                Ok(path) => println!("{reference} -> {path}"),
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "cluster-status" => {
            let shards = parse_shards(&parsed);
            let mut fabric = DistributionFabric::new(shards, pfs);
            // drive the whole catalog through the cluster, as a site's
            // nightly sync would
            for reference in registry.list() {
                if let Err(e) = fabric.request(&registry, &reference, "admin") {
                    eprintln!("shifterimg: {reference}: {e}");
                }
            }
            fabric.tick(&registry, 1e9);

            let mut table = Table::new(
                &format!("cluster status ({shards} shards)"),
                &[
                    "shard", "backlog", "ready", "failed", "images",
                    "max-wait", "active",
                ],
            );
            for s in fabric.cluster().cluster_status() {
                table.row(&[
                    s.shard.to_string(),
                    s.backlog.to_string(),
                    s.ready.to_string(),
                    s.failed.to_string(),
                    s.images.to_string(),
                    format!("{:.1}s", s.max_queue_wait_secs),
                    s.active.unwrap_or_else(|| "-".to_string()),
                ]);
            }
            print!("{}", table.render());

            let cas = fabric.cluster().cas();
            println!(
                "storm drained in {:.1}s (makespan across shards)",
                fabric.cluster().makespan_secs()
            );
            if let Some(wait) = fabric.queue_wait_stats() {
                println!(
                    "queue wait across {} jobs: p50 {:.1}s, p95 {:.1}s, \
                     p99 {:.1}s, worst {:.1}s",
                    wait.n, wait.p50, wait.p95, wait.p99, wait.worst
                );
            }
            println!(
                "cas: {} blobs, {:.1} MB stored / {:.1} MB logical \
                 (dedup {:.2}x, {:.1} MB saved)",
                cas.blob_count(),
                cas.stored_bytes() as f64 / 1e6,
                cas.logical_bytes() as f64 / 1e6,
                cas.dedup_ratio(),
                cas.saved_bytes() as f64 / 1e6,
            );
        }
        [cmd, rest @ ..] if cmd == "launch" && !rest.is_empty() => {
            let reference = &rest[0];
            let command: Vec<&str> = if rest.len() > 1 {
                rest[1..].iter().map(|s| s.as_str()).collect()
            } else {
                vec!["true"]
            };
            let shards = parse_shards(&parsed);
            let nodes: u32 = match parsed.get("nodes").unwrap_or("64").parse() {
                Ok(n) if n >= 1 => n,
                _ => {
                    eprintln!("shifterimg: --nodes must be a positive integer");
                    usage();
                }
            };
            let gpus: u32 = match parsed.get("gpus").unwrap_or("0").parse() {
                Ok(n) => n,
                _ => {
                    eprintln!("shifterimg: --gpus must be an integer");
                    usage();
                }
            };
            let cluster = if parsed.has("hetero") {
                if nodes < 2 {
                    eprintln!("shifterimg: --hetero needs --nodes >= 2");
                    usage();
                }
                LaunchCluster::daint_linux_split(nodes)
            } else {
                LaunchCluster::homogeneous(&profile, nodes)
            };
            let mut fabric = DistributionFabric::new(shards, pfs);
            let mut job = JobSpec::new(reference, &command, nodes);
            if gpus > 0 {
                job = job.with_gpus(gpus);
            }
            if parsed.has("mpi") {
                job = job.with_mpi();
            }
            let scheduler = LaunchScheduler::new(&cluster, &registry);
            match scheduler.launch(&mut fabric, &job) {
                Ok(report) => {
                    print!("{}", report.render());
                    if report.failed() > 0 {
                        std::process::exit(1);
                    }
                }
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "storm" => {
            let shards = parse_shards(&parsed);
            let nodes: u32 = match parsed.get("nodes").unwrap_or("256").parse()
            {
                Ok(n) if n >= 1 => n,
                _ => {
                    eprintln!("shifterimg: --nodes must be a positive integer");
                    usage();
                }
            };
            let tenants: u32 =
                match parsed.get("tenants").unwrap_or("8").parse() {
                    Ok(n) if n >= 1 => n,
                    _ => {
                        eprintln!(
                            "shifterimg: --tenants must be a positive integer"
                        );
                        usage();
                    }
                };
            let jobs: u32 = match parsed.get("jobs").unwrap_or("64").parse() {
                Ok(n) if n >= 1 => n,
                _ => {
                    eprintln!("shifterimg: --jobs must be a positive integer");
                    usage();
                }
            };
            let arrival_rate: f64 =
                match parsed.get("arrival-rate").unwrap_or("2.4").parse() {
                    Ok(r) if r > 0.0 => r,
                    _ => {
                        eprintln!(
                            "shifterimg: --arrival-rate must be positive"
                        );
                        usage();
                    }
                };
            let duration: f64 = match parsed.get("duration") {
                None => f64::INFINITY,
                Some(v) => match v.parse() {
                    Ok(d) if d > 0.0 => d,
                    _ => {
                        eprintln!("shifterimg: --duration must be positive");
                        usage();
                    }
                },
            };
            let policy = match parsed.get("policy").unwrap_or("fair") {
                "fair" | "fair-share" => SchedulingPolicy::FairShare,
                "fifo" => SchedulingPolicy::Fifo,
                _ => {
                    eprintln!("shifterimg: --policy must be fair or fifo");
                    usage();
                }
            };
            let seed: u64 = match parsed.get("seed").unwrap_or("7").parse() {
                Ok(s) => s,
                _ => {
                    eprintln!("shifterimg: --seed must be an integer");
                    usage();
                }
            };
            let cluster = if parsed.has("hetero") {
                if nodes < 2 {
                    eprintln!("shifterimg: --hetero needs --nodes >= 2");
                    usage();
                }
                LaunchCluster::daint_linux_split(nodes)
            } else {
                LaunchCluster::homogeneous(&profile, nodes)
            };
            let model = TrafficModel {
                tenants,
                jobs,
                arrival_rate_per_min: arrival_rate,
                duration_secs: duration,
                max_width: (nodes / 2).max(1),
                seed,
                ..TrafficModel::default()
            };
            let stream = model.generate(&cluster);
            let mut fabric = DistributionFabric::new(shards, pfs);
            let report = FairShareScheduler::new(&cluster, &registry)
                .with_policy(policy)
                .run(&mut fabric, &stream);
            print!("{}", report.render());
            if report.failed() > 0 {
                std::process::exit(1);
            }
        }
        _ => usage(),
    }
}

fn parse_shards(parsed: &shifter_rs::util::cli::ParsedArgs) -> usize {
    match parsed.get("shards").unwrap_or("4").parse() {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("shifterimg: --shards must be a positive integer");
            usage();
        }
    }
}
