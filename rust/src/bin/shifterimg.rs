//! `shifterimg` — the Image Gateway CLI (§III.B), built entirely on the
//! [`Site`] facade (DESIGN.md S21): every subcommand declares the site
//! once through `SiteBuilder` and goes through the typed `Site`
//! operations — no hand-wired fabric/scheduler stacks.
//!
//! ```text
//! shifterimg [--system=daint] pull docker:ubuntu:xenial
//! shifterimg [--system=daint] images
//! shifterimg [--system=daint] lookup docker:ubuntu:xenial
//! shifterimg [--system=daint] [--mpi] [--gpus=LIST] run <ref> [cmd...]
//! shifterimg [--system=daint] [--shards=4] cluster-status
//! shifterimg [--system=daint] [--shards=4] [--nodes=64] [--gpus=1] \
//!     [--mpi] [--hetero] launch <ref> [cmd...]
//! shifterimg [--system=daint] [--shards=4] [--nodes=256] [--hetero] \
//!     [--tenants=8] [--jobs=64] [--arrival-rate=2.4] [--duration=S] \
//!     [--policy=fair|fifo] [--seed=N] storm
//! shifterimg [--nodes=64] [--tenants=4] [--jobs=32] \
//!     [--trace=shifter_trace.jsonl] trace
//! shifterimg [--sites=3] [--nodes=64] [--route=data-locality] \
//!     [--overflow-threshold=300] [--tenants=8] [--jobs=64] federate
//! ```
//!
//! `pull`/`lookup`/`images`/`run` are the paper's §III.B end-user
//! workflow. `cluster-status` drives the full registry catalog through
//! the sharded fabric (DESIGN.md S18) and prints per-shard queue/image
//! state, the CAS dedup accounting, and the per-partition host-extension
//! capability vectors (S22). `launch` runs one cluster-scale job through
//! the orchestrator (S19); `storm` runs the multi-tenant traffic
//! simulation (S20) under a pluggable scheduling policy. `federate`
//! declares a 2–4 member fleet of heterogeneous sites (DESIGN.md S27)
//! and drives one storm through capability-aware routing, cross-site
//! replication, and burst overflow. `--hetero`
//! splits the node range into a Piz Daint partition and a Linux Cluster
//! partition (different GPU generations, driver versions, host MPIs and
//! fabric transports). `--net` requests the host fabric via the
//! specialized-network extension (`SHIFTER_NET=host`).
//!
//! Every subcommand honors `--trace=<path>` (or `SHIFTER_TRACE=<path>`):
//! the site records structured telemetry (DESIGN.md S23) and dumps the
//! span tree as Chrome trace-event JSONL for Perfetto. `trace` is the
//! one-shot profiling subcommand: it replays a deterministic job storm
//! with telemetry forced on and writes the trace (default
//! `shifter_trace.jsonl`) plus a counter summary. `cluster-status`
//! likewise always records, so its per-shard counter table is live.

use shifter_rs::federation::{
    routing_policy_by_name, Federation, FederationStorm,
};
use shifter_rs::launch::JobSpec;
use shifter_rs::metrics::Table;
use shifter_rs::shifter::RunOptions;
use shifter_rs::tenancy::{policy_by_name, SchedulingPolicy};
use shifter_rs::util::cli::{CliSpec, ParsedArgs};
use shifter_rs::{Site, SiteBuilder, StormSpec, SystemProfile};

fn usage() -> ! {
    eprintln!(
        "usage: shifterimg [options] <subcommand>\n\
         \n\
         subcommands:\n\
         \x20 pull <ref>            pull an image through the site fabric\n\
         \x20 images                list registry and site images\n\
         \x20 lookup <ref>          pull (if needed) and print the PFS path\n\
         \x20 run <ref> [cmd..]     run one container on node 0\n\
         \x20 cluster-status        drive the catalog through the sharded\n\
         \x20                       fabric and print per-shard state\n\
         \x20 launch <ref> [cmd..]  one cluster-scale containerized job\n\
         \x20 storm                 multi-tenant job-storm simulation\n\
         \x20 trace                 replay a storm with telemetry on and\n\
         \x20                       dump a Chrome/Perfetto trace\n\
         \x20 federate              multi-site federation storm (routing,\n\
         \x20                       replication, burst overflow)\n\
         \n\
         common options:\n\
         \x20 --system=laptop|cluster|daint   host profile (default daint)\n\
         \x20 --shards=N                      gateway shards (default 4)\n\
         \x20 --nodes=N                       cluster width (launch: 64,\n\
         \x20                                 storm: 256, trace: 64)\n\
         \x20 --hetero                        split nodes into Piz Daint +\n\
         \x20                                 Linux Cluster partitions\n\
         \x20 --trace=PATH          record telemetry and write the span\n\
         \x20                       tree as Chrome trace-event JSONL\n\
         \x20                       (SHIFTER_TRACE=PATH does the same)\n\
         \n\
         run options:\n\
         \x20 --gpus=LIST           set CUDA_VISIBLE_DEVICES (GPU support)\n\
         \x20 --mpi                 activate the MPI ABI swap\n\
         \x20 --net                 request the host fabric (SHIFTER_NET)\n\
         \n\
         launch options:\n\
         \x20 --gpus=N              request --gres=gpu:N per node\n\
         \x20 --mpi                 activate the MPI ABI swap\n\
         \x20 --net                 request the host fabric on every node\n\
         \n\
         storm options:\n\
         \x20 --tenants=N           simulated tenants (default 8)\n\
         \x20 --jobs=N              jobs to synthesize (default 64)\n\
         \x20 --arrival-rate=R      aggregate arrivals per minute (2.4)\n\
         \x20 --duration=SECS       stop generating arrivals after SECS\n\
         \x20 --policy=fair|fifo    queue policy (default fair)\n\
         \x20 --seed=N              traffic PRNG seed (default 7)\n\
         \n\
         trace options: storm knobs (defaults --tenants=4 --jobs=32)\n\
         \x20 plus --trace=PATH for the output (shifter_trace.jsonl)\n\
         \n\
         federate options: storm knobs, plus\n\
         \x20 --sites=N             member sites, 2-4 (default 3); the\n\
         \x20                       fleet cycles daint/cluster profiles\n\
         \x20 --nodes=N             width of the first member site;\n\
         \x20                       later members get N/2 (default 64)\n\
         \x20 --route=NAME          data-locality | least-loaded |\n\
         \x20                       capability-first | random |\n\
         \x20                       pinned-home (default data-locality)\n\
         \x20 --overflow-threshold=SECS  spill jobs whose queue-wait\n\
         \x20                       estimate exceeds SECS (default 300;\n\
         \x20                       0 disables burst overflow)"
    );
    std::process::exit(2);
}

/// Print a typed error with its full `source()` chain and exit nonzero —
/// every operational failure routes through here, so a user always sees
/// the `SiteError` (and its layer-level cause) rather than a panic.
fn die(err: &dyn std::error::Error) -> ! {
    shifter_rs::util::cli::die("shifterimg", err)
}

fn main() {
    let spec = CliSpec::new(
        &[
            ("system", true),
            ("shards", true),
            ("nodes", true),
            ("gpus", true),
            ("mpi", false),
            ("net", false),
            ("hetero", false),
            ("tenants", true),
            ("jobs", true),
            ("arrival-rate", true),
            ("duration", true),
            ("policy", true),
            ("seed", true),
            ("trace", true),
            ("sites", true),
            ("route", true),
            ("overflow-threshold", true),
        ],
        // stop option parsing at the subcommand, so a containerized
        // command like `launch <ref> ls --color` keeps its own flags
        true,
    );
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifterimg: {e}");
            usage();
        }
    };
    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        _ => usage(),
    };

    match parsed.positionals.as_slice() {
        [cmd, reference] if cmd == "pull" => {
            let mut site = build_site(site_builder(&profile, &parsed, parse_nodes(&parsed, 1), false));
            match site.pull(reference) {
                Ok(pull) => {
                    println!(
                        "{}: READY in {:.1}s (queue wait {:.1}s, download \
                         {:.1}s, expand {:.1}s, squashfs {:.1}s, store \
                         {:.1}s)\n  -> {}",
                        pull.reference,
                        pull.turnaround_secs,
                        pull.queue_wait_secs,
                        pull.download_secs,
                        pull.expand_secs,
                        pull.convert_secs,
                        pull.store_secs,
                        pull.pfs_path,
                    );
                    maybe_write_trace(&site, &parsed, None);
                }
                Err(e) => die(&e),
            }
        }
        [cmd] if cmd == "images" => {
            let site = build_site(site_builder(&profile, &parsed, parse_nodes(&parsed, 1), false));
            // a fresh site has nothing pulled; list the registry too so
            // the demo binary is useful on its own
            let registry = site.registry().list();
            println!("registry ({}):", registry.len());
            for r in registry {
                println!("  {r}");
            }
            let images = site.images();
            println!("site ({}):", images.len());
            for r in images {
                println!("  {r}");
            }
        }
        [cmd, reference] if cmd == "lookup" => {
            let mut site = build_site(site_builder(&profile, &parsed, parse_nodes(&parsed, 1), false));
            match site.pull(reference) {
                Ok(pull) => println!("{reference} -> {}", pull.pfs_path),
                Err(e) => die(&e),
            }
        }
        [cmd, rest @ ..] if cmd == "run" && !rest.is_empty() => {
            let reference = &rest[0];
            let command: Vec<&str> = if rest.len() > 1 {
                rest[1..].iter().map(|s| s.as_str()).collect()
            } else {
                vec!["true"]
            };
            let mut site = build_site(site_builder(&profile, &parsed, parse_nodes(&parsed, 1), false));
            let mut opts = RunOptions::new(reference, &command);
            if parsed.has("mpi") {
                opts = opts.with_mpi();
            }
            if parsed.has("net") {
                opts = opts.with_env("SHIFTER_NET", "host");
            }
            if let Some(gpus) = parsed.get("gpus") {
                opts = opts.with_env("CUDA_VISIBLE_DEVICES", gpus);
            }
            match site.run(&opts) {
                Ok(container) => match container.exec(&command) {
                    Ok(out) => {
                        print!("{out}");
                        if !out.is_empty() && !out.ends_with('\n') {
                            println!();
                        }
                        eprintln!(
                            "(container start-up overhead: {:.1} ms)",
                            container.startup_overhead_secs() * 1e3
                        );
                        maybe_write_trace(&site, &parsed, None);
                    }
                    Err(e) => die(&e),
                },
                Err(e) => die(&e),
            }
        }
        [cmd] if cmd == "cluster-status" => {
            // always record: the per-shard telemetry table below is part
            // of the status report
            let mut site = build_site(
                site_builder(&profile, &parsed, parse_nodes(&parsed, 1), false)
                    .telemetry(true),
            );
            // drive the whole catalog through the cluster, as a site's
            // nightly sync would
            let refs = site.registry().list();
            for (reference, e) in site.prefetch(&refs) {
                eprintln!("shifterimg: {reference}: {e}");
            }

            let shards = site.fabric().cluster().shard_count();
            let mut table = Table::new(
                &format!("cluster status ({shards} shards)"),
                &[
                    "shard", "backlog", "ready", "failed", "images",
                    "max-wait", "active",
                ],
            );
            for s in site.fabric().cluster().cluster_status() {
                table.row(&[
                    s.shard.to_string(),
                    s.backlog.to_string(),
                    s.ready.to_string(),
                    s.failed.to_string(),
                    s.images.to_string(),
                    format!("{:.1}s", s.max_queue_wait_secs),
                    s.active.unwrap_or_else(|| "-".to_string()),
                ]);
            }
            print!("{}", table.render());

            println!(
                "storm drained in {:.1}s (makespan across shards)",
                site.fabric().cluster().makespan_secs()
            );
            if let Some(wait) = site.fabric().queue_wait_stats() {
                println!(
                    "queue wait across {} jobs: p50 {:.1}s, p95 {:.1}s, \
                     p99 {:.1}s, worst {:.1}s",
                    wait.n, wait.p50, wait.p95, wait.p99, wait.worst
                );
            }
            let cas = site.fabric().cluster().cas();
            println!(
                "cas: {} blobs, {:.1} MB stored / {:.1} MB logical \
                 (dedup {:.2}x, {:.1} MB saved)",
                cas.blob_count(),
                cas.stored_bytes() as f64 / 1e6,
                cas.logical_bytes() as f64 / 1e6,
                cas.dedup_ratio(),
                cas.saved_bytes() as f64 / 1e6,
            );

            // per-shard telemetry counters (S23): request routing,
            // coalescing wins, and observed pull-queue depth
            let tel = site.telemetry();
            let mut tel_table = Table::new(
                "shard telemetry",
                &["shard", "requests", "coalesced", "queue-p95"],
            );
            for s in 0..shards {
                let depth = tel
                    .histogram(&format!("shard.{s}.queue_depth"))
                    .map(|h| format!("{:.0}", h.p95))
                    .unwrap_or_else(|| "-".to_string());
                tel_table.row(&[
                    s.to_string(),
                    tel.counter(&format!("shard.{s}.requests")).to_string(),
                    tel.counter(&format!("shard.{s}.coalesced")).to_string(),
                    depth,
                ]);
            }
            print!("{}", tel_table.render());
            println!(
                "node caches: {} hits, {} cold fills, {} evictions",
                tel.counter("fabric.cache_hits"),
                tel.counter("fabric.cold_fills"),
                tel.counter("fabric.evictions"),
            );

            // per-partition host-extension capability vectors (S22)
            let mut ext_table = Table::new(
                "extension capabilities",
                &["partition", "extension", "available", "detail"],
            );
            for (partition, caps) in site.capabilities() {
                for cap in caps {
                    let verdict = if cap.available { "yes" } else { "no" };
                    ext_table.row(&[
                        partition.clone(),
                        cap.extension.to_string(),
                        verdict.to_string(),
                        cap.detail.clone(),
                    ]);
                }
            }
            print!("{}", ext_table.render());
            maybe_write_trace(&site, &parsed, None);
        }
        [cmd, rest @ ..] if cmd == "launch" && !rest.is_empty() => {
            let reference = &rest[0];
            let command: Vec<&str> = if rest.len() > 1 {
                rest[1..].iter().map(|s| s.as_str()).collect()
            } else {
                vec!["true"]
            };
            let nodes = parse_nodes(&parsed, 64);
            let gpus: u32 = match parsed.get("gpus").unwrap_or("0").parse() {
                Ok(n) => n,
                _ => {
                    eprintln!("shifterimg: --gpus must be an integer");
                    usage();
                }
            };
            let mut site = build_site(site_builder(
                &profile,
                &parsed,
                nodes,
                parsed.has("hetero"),
            ));
            let mut job = JobSpec::new(reference, &command, nodes);
            if gpus > 0 {
                job = job.with_gpus(gpus);
            }
            if parsed.has("mpi") {
                job = job.with_mpi();
            }
            if parsed.has("net") {
                job = job.with_env("SHIFTER_NET", "host");
            }
            match site.launch(&job) {
                Ok(report) => {
                    print!("{}", report.render());
                    maybe_write_trace(&site, &parsed, None);
                    if report.failed() > 0 {
                        std::process::exit(1);
                    }
                }
                Err(e) => die(&e),
            }
        }
        [cmd] if cmd == "storm" => {
            let nodes = parse_nodes(&parsed, 256);
            let knobs = parse_storm_knobs(&parsed, "8", "64");
            let mut site = build_site(
                site_builder(&profile, &parsed, nodes, parsed.has("hetero"))
                    .scheduling_policy(knobs.policy)
                    // strict retry: deterministic storm timings (the
                    // multi-tenant scheduler's own default)
                    .retry_policy(shifter_rs::launch::RetryPolicy::strict())
                    .seed(knobs.seed),
            );
            let spec = StormSpec::new()
                .tenants(knobs.tenants)
                .jobs(knobs.jobs)
                .arrival_rate_per_min(knobs.arrival_rate)
                .duration_secs(knobs.duration);
            let report = match site.run_storm(&spec) {
                Ok(r) => r,
                Err(e) => die(&e),
            };
            print!("{}", report.render());
            maybe_write_trace(&site, &parsed, None);
            if report.failed() > 0 {
                std::process::exit(1);
            }
        }
        [cmd] if cmd == "trace" => {
            // one-shot profiling: replay a deterministic storm with
            // telemetry forced on and dump the Chrome/Perfetto trace
            let nodes = parse_nodes(&parsed, 64);
            let knobs = parse_storm_knobs(&parsed, "4", "32");
            let mut site = build_site(
                site_builder(&profile, &parsed, nodes, parsed.has("hetero"))
                    .scheduling_policy(knobs.policy)
                    .retry_policy(shifter_rs::launch::RetryPolicy::strict())
                    .seed(knobs.seed)
                    .telemetry(true),
            );
            let spec = StormSpec::new()
                .tenants(knobs.tenants)
                .jobs(knobs.jobs)
                .arrival_rate_per_min(knobs.arrival_rate)
                .duration_secs(knobs.duration);
            let report = match site.run_storm(&spec) {
                Ok(r) => r,
                Err(e) => die(&e),
            };
            print!("{}", report.render());
            let tel = site.telemetry();
            let mut counters = Table::new(
                &format!("telemetry ({} spans)", tel.span_count()),
                &["counter", "value"],
            );
            for (name, value) in tel.counters() {
                counters.row(&[name, value.to_string()]);
            }
            print!("{}", counters.render());
            maybe_write_trace(&site, &parsed, Some("shifter_trace.jsonl"));
            if report.failed() > 0 {
                std::process::exit(1);
            }
        }
        [cmd] if cmd == "federate" => {
            // a 2-4 member fleet of heterogeneous sites (DESIGN.md S27):
            // the first member is the wide "home" center, later members
            // are half-width peers alternating the two cluster profiles
            let site_count: usize =
                match parsed.get("sites").unwrap_or("3").parse() {
                    Ok(n) if (2..=4).contains(&n) => n,
                    _ => {
                        eprintln!("shifterimg: --sites must be 2..=4");
                        usage();
                    }
                };
            let nodes = parse_nodes(&parsed, 64);
            let knobs = parse_storm_knobs(&parsed, "8", "64");
            let route = parsed.get("route").unwrap_or("data-locality");
            let Some(routing) =
                routing_policy_by_name(route, knobs.seed, site_count)
            else {
                eprintln!(
                    "shifterimg: --route must be data-locality, \
                     least-loaded, capability-first, random, or \
                     pinned-home"
                );
                usage();
            };
            let threshold: f64 = match parsed
                .get("overflow-threshold")
                .unwrap_or("300")
                .parse()
            {
                Ok(t) if t >= 0.0 => t,
                _ => {
                    eprintln!(
                        "shifterimg: --overflow-threshold must be >= 0"
                    );
                    usage();
                }
            };
            let policy_name = parsed.get("policy").unwrap_or("fair");
            let want_trace = trace_path(&parsed).is_some();
            let mut builder = Federation::builder()
                .routing(routing)
                .seed(knobs.seed)
                .telemetry(want_trace);
            if threshold > 0.0 {
                builder = builder.overflow_threshold_secs(threshold);
            }
            for i in 0..site_count {
                let (name, profile) = fleet_member(i);
                let width = if i == 0 { nodes } else { (nodes / 2).max(1) };
                let Some(policy) = policy_by_name(policy_name) else {
                    eprintln!("shifterimg: --policy must be fair or fifo");
                    usage();
                };
                builder = builder.site(
                    name,
                    Site::builder()
                        .profile(profile)
                        .nodes(width)
                        .gateway_shards(parse_shards(&parsed))
                        .scheduling_policy(policy)
                        .retry_policy(
                            shifter_rs::launch::RetryPolicy::strict(),
                        )
                        .seed(knobs.seed),
                );
            }
            let mut fed = match builder.build() {
                Ok(fed) => fed,
                Err(e) => {
                    eprintln!("shifterimg: invalid federation: {e}");
                    std::process::exit(2);
                }
            };
            let mut spec = FederationStorm::new()
                .tenants(knobs.tenants)
                .jobs(knobs.jobs)
                .arrival_rate_per_min(knobs.arrival_rate)
                .duration_secs(knobs.duration)
                .seed(knobs.seed);
            if let Some(path) = trace_path(&parsed) {
                spec = spec.trace_path(path);
            }
            let report = match fed.run_storm(&spec) {
                Ok(r) => r,
                Err(e) => die(&e),
            };
            print!("{}", report.render());
            if let Some(path) = trace_path(&parsed) {
                eprintln!(
                    "trace: {} spans -> {path} (open in Perfetto or \
                     chrome://tracing)",
                    fed.telemetry().span_count()
                );
            }
            let failed = report.records.len() - report.completed();
            if failed > 0 {
                std::process::exit(1);
            }
        }
        _ => usage(),
    }
}

/// The federate fleet roster: member `i`'s name and host profile. The
/// first member is the flagship Cray, later members alternate the two
/// cluster profiles so capability vectors and fabric transports differ
/// across the fleet.
fn fleet_member(i: usize) -> (&'static str, SystemProfile) {
    match i {
        0 => ("daint", SystemProfile::piz_daint()),
        1 => ("cluster", SystemProfile::linux_cluster()),
        2 => ("alps", SystemProfile::piz_daint()),
        _ => ("edge", SystemProfile::linux_cluster()),
    }
}

/// The common site declaration every subcommand shares: profile (or,
/// when the subcommand honors `--hetero`, the two-partition split),
/// node count, shard count. Single-node subcommands pass `hetero:
/// false` — they ignore the flag exactly as they did before the facade.
fn site_builder(
    profile: &SystemProfile,
    parsed: &ParsedArgs,
    nodes: u32,
    hetero: bool,
) -> SiteBuilder {
    let builder = Site::builder()
        .gateway_shards(parse_shards(parsed))
        // telemetry turns on whenever a trace destination is requested
        // (subcommands that always record chain `.telemetry(true)`)
        .telemetry(trace_path(parsed).is_some());
    if hetero {
        if nodes < 2 {
            eprintln!("shifterimg: --hetero needs --nodes >= 2");
            usage();
        }
        builder.hetero_daint_linux(nodes)
    } else {
        builder.profile(profile.clone()).nodes(nodes)
    }
}

/// The requested trace destination: `--trace=<path>` wins over the
/// `SHIFTER_TRACE` environment knob; `None` means no trace.
fn trace_path(parsed: &ParsedArgs) -> Option<String> {
    parsed
        .get("trace")
        .map(String::from)
        .or_else(|| std::env::var("SHIFTER_TRACE").ok())
}

/// Dump the site's span tree as Chrome trace-event JSONL if the user
/// asked for a trace (explicitly, or — for the `trace` subcommand — via
/// `default`), and say where it went.
fn maybe_write_trace(
    site: &Site,
    parsed: &ParsedArgs,
    default: Option<&str>,
) {
    let Some(path) =
        trace_path(parsed).or_else(|| default.map(String::from))
    else {
        return;
    };
    if let Err(e) = std::fs::write(&path, site.telemetry().chrome_trace_jsonl())
    {
        eprintln!("shifterimg: cannot write trace {path}: {e}");
        std::process::exit(1);
    }
    eprintln!(
        "trace: {} spans -> {path} (open in Perfetto or chrome://tracing)",
        site.telemetry().span_count()
    );
}

/// The storm-shaped knobs `storm` and `trace` share; the two
/// subcommands differ only in their tenant/job defaults.
struct StormKnobs {
    tenants: u32,
    jobs: u32,
    arrival_rate: f64,
    duration: f64,
    policy: Box<dyn SchedulingPolicy>,
    seed: u64,
}

fn parse_storm_knobs(
    parsed: &ParsedArgs,
    default_tenants: &str,
    default_jobs: &str,
) -> StormKnobs {
    let tenants: u32 =
        match parsed.get("tenants").unwrap_or(default_tenants).parse() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!(
                    "shifterimg: --tenants must be a positive integer"
                );
                usage();
            }
        };
    let jobs: u32 = match parsed.get("jobs").unwrap_or(default_jobs).parse()
    {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("shifterimg: --jobs must be a positive integer");
            usage();
        }
    };
    let arrival_rate: f64 =
        match parsed.get("arrival-rate").unwrap_or("2.4").parse() {
            Ok(r) if r > 0.0 => r,
            _ => {
                eprintln!("shifterimg: --arrival-rate must be positive");
                usage();
            }
        };
    let duration: f64 = match parsed.get("duration") {
        None => f64::INFINITY,
        Some(v) => match v.parse() {
            Ok(d) if d > 0.0 => d,
            _ => {
                eprintln!("shifterimg: --duration must be positive");
                usage();
            }
        },
    };
    let Some(policy) = policy_by_name(parsed.get("policy").unwrap_or("fair"))
    else {
        eprintln!("shifterimg: --policy must be fair or fifo");
        usage();
    };
    let seed: u64 = match parsed.get("seed").unwrap_or("7").parse() {
        Ok(s) => s,
        _ => {
            eprintln!("shifterimg: --seed must be an integer");
            usage();
        }
    };
    StormKnobs {
        tenants,
        jobs,
        arrival_rate,
        duration,
        policy,
        seed,
    }
}

/// Build the site, or exit with the builder's typed validation error.
fn build_site(builder: SiteBuilder) -> Site {
    match builder.build() {
        Ok(site) => site,
        Err(e) => {
            eprintln!("shifterimg: invalid site: {e}");
            std::process::exit(2);
        }
    }
}

fn parse_shards(parsed: &ParsedArgs) -> usize {
    match parsed.get("shards").unwrap_or("4").parse() {
        Ok(n) if n >= 1 => n,
        _ => {
            eprintln!("shifterimg: --shards must be a positive integer");
            usage();
        }
    }
}

fn parse_nodes(parsed: &ParsedArgs, default: u32) -> u32 {
    match parsed.get("nodes") {
        None => default,
        Some(v) => match v.parse() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("shifterimg: --nodes must be a positive integer");
                usage();
            }
        },
    }
}
