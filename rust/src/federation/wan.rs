//! The WAN link model: per-site-pair latency/bandwidth plus an origin
//! registry uplink, pricing every cross-site replication.

use std::collections::BTreeMap;

/// One directionless WAN link: fixed one-way latency plus a shared
/// bandwidth. Transfers are priced `latency + bytes / bandwidth` —
/// the same first-order model the registry uses for center uplinks,
/// deliberately ignoring congestion (replications are rare next to
/// intra-site traffic and the simulation charges them serially per
/// image anyway).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WanLink {
    /// One-way latency, seconds.
    pub latency_secs: f64,
    /// Sustained bandwidth, bytes per second.
    pub bytes_per_sec: f64,
}

impl WanLink {
    /// Seconds to move `bytes` over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_secs + bytes as f64 / self.bytes_per_sec
    }
}

/// Default site-pair link: a dedicated 10 Gbit/s research-network path
/// with continental latency.
pub const DEFAULT_SITE_LINK: WanLink = WanLink {
    latency_secs: 0.045,
    bytes_per_sec: 1.25e9,
};

/// Default origin-registry uplink: the public registry's ~640 Mbit/s
/// ([`crate::registry::Registry::dockerhub`]) with internet latency —
/// pulling from a peer site is ~15x faster, which is the whole point
/// of federation-level replication.
pub const DEFAULT_ORIGIN_LINK: WanLink = WanLink {
    latency_secs: 0.25,
    bytes_per_sec: 80e6,
};

/// Per-site-pair WAN topology. Links are symmetric and keyed by the
/// *ordered* name pair, so `link("a", "b")` and `link("b", "a")` see
/// the same path; pairs without an explicit override use the default
/// link, and pulls that fall through to the origin registry are priced
/// over the origin uplink.
#[derive(Debug, Clone)]
pub struct WanModel {
    default: WanLink,
    origin: WanLink,
    links: BTreeMap<(String, String), WanLink>,
}

impl Default for WanModel {
    fn default() -> WanModel {
        WanModel::new()
    }
}

impl WanModel {
    /// A topology where every pair uses [`DEFAULT_SITE_LINK`] and the
    /// origin uses [`DEFAULT_ORIGIN_LINK`].
    pub fn new() -> WanModel {
        WanModel {
            default: DEFAULT_SITE_LINK,
            origin: DEFAULT_ORIGIN_LINK,
            links: BTreeMap::new(),
        }
    }

    /// Replace the default link used by pairs without an override.
    pub fn set_default(&mut self, link: WanLink) {
        self.default = link;
    }

    /// Replace the origin-registry uplink.
    pub fn set_origin(&mut self, link: WanLink) {
        self.origin = link;
    }

    /// Override the link between `a` and `b` (order-insensitive).
    pub fn set_link(&mut self, a: &str, b: &str, link: WanLink) {
        self.links.insert(Self::key(a, b), link);
    }

    /// The link between `a` and `b` (order-insensitive; the default
    /// when no override exists).
    pub fn link(&self, a: &str, b: &str) -> WanLink {
        self.links
            .get(&Self::key(a, b))
            .copied()
            .unwrap_or(self.default)
    }

    /// The origin-registry uplink any site pays when no peer holds the
    /// missing chunks.
    pub fn origin(&self) -> WanLink {
        self.origin
    }

    fn key(a: &str, b: &str) -> (String, String) {
        if a <= b {
            (a.to_string(), b.to_string())
        } else {
            (b.to_string(), a.to_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn links_are_symmetric_and_default_fills_gaps() {
        let mut wan = WanModel::new();
        let fat = WanLink {
            latency_secs: 0.002,
            bytes_per_sec: 1e10,
        };
        wan.set_link("b", "a", fat);
        assert_eq!(wan.link("a", "b"), fat);
        assert_eq!(wan.link("b", "a"), fat);
        assert_eq!(wan.link("a", "c"), DEFAULT_SITE_LINK);
    }

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let link = WanLink {
            latency_secs: 0.1,
            bytes_per_sec: 1000.0,
        };
        assert_eq!(link.transfer_secs(0), 0.0);
        let secs = link.transfer_secs(500);
        assert!((secs - 0.6).abs() < 1e-12);
    }
}
