//! The declarative federation builder: member sites, WAN topology,
//! routing policy, and burst-overflow knobs in one place, validated
//! once at [`FederationBuilder::build`].

use std::sync::Arc;

use crate::distrib::Chunker;
use crate::site::SiteBuilder;
use crate::telemetry::Telemetry;

use super::error::FederationError;
use super::index::ReplicaIndex;
use super::routing::{DataLocality, RoutingPolicy};
use super::wan::{WanLink, WanModel};
use super::{Federation, SiteEntry, FEDERATION_CHUNK_TARGET_BYTES};

/// Chunker seed shared with the S25 CAS so federation manifests and
/// site-local chunk stores agree on chunk identity.
const FEDERATION_CHUNK_SEED: u64 = 0xC0FFEE;

/// Declares a [`Federation`]: named member sites (each a full
/// [`SiteBuilder`]), the WAN topology between them, the routing
/// policy, and the burst-overflow threshold. `build()` validates the
/// combination, injects one shared [`Telemetry`] recorder into every
/// member (so a federation storm produces one coherent Chrome trace),
/// and wires the replica index — exactly once.
///
/// ```
/// use shifter_rs::{Federation, SiteBuilder, SystemProfile};
///
/// let fed = Federation::builder()
///     .site(
///         "daint",
///         SiteBuilder::new()
///             .profile(SystemProfile::piz_daint())
///             .nodes(8),
///     )
///     .site(
///         "cluster",
///         SiteBuilder::new()
///             .profile(SystemProfile::linux_cluster())
///             .nodes(8),
///     )
///     .overflow_threshold_secs(120.0)
///     .build()
///     .unwrap();
/// assert_eq!(fed.site_names(), vec!["daint", "cluster"]);
/// ```
pub struct FederationBuilder {
    sites: Vec<(String, SiteBuilder)>,
    links: Vec<(String, String, WanLink)>,
    default_link: Option<WanLink>,
    origin_link: Option<WanLink>,
    routing: Box<dyn RoutingPolicy>,
    overflow_threshold: Option<f64>,
    telemetry: bool,
    seed: u64,
}

impl Default for FederationBuilder {
    fn default() -> FederationBuilder {
        FederationBuilder::new()
    }
}

impl FederationBuilder {
    /// An empty federation: no sites yet, default WAN links,
    /// [`DataLocality`] routing, overflow disabled, telemetry off,
    /// seed 7.
    pub fn new() -> FederationBuilder {
        FederationBuilder {
            sites: Vec::new(),
            links: Vec::new(),
            default_link: None,
            origin_link: None,
            routing: Box::new(DataLocality),
            overflow_threshold: None,
            telemetry: false,
            seed: 7,
        }
    }

    /// Add a member site under `name`. Declaration order is federation
    /// order: site indices, routing tie-breaks, and report rows all
    /// follow it.
    pub fn site(
        mut self,
        name: &str,
        builder: SiteBuilder,
    ) -> FederationBuilder {
        self.sites.push((name.to_string(), builder));
        self
    }

    /// Override the WAN link between two member sites
    /// (order-insensitive). Pairs without an override use the default
    /// link.
    pub fn wan_link(
        mut self,
        a: &str,
        b: &str,
        latency_secs: f64,
        bytes_per_sec: f64,
    ) -> FederationBuilder {
        self.links.push((
            a.to_string(),
            b.to_string(),
            WanLink {
                latency_secs,
                bytes_per_sec,
            },
        ));
        self
    }

    /// Replace the default site-pair link
    /// ([`super::wan::DEFAULT_SITE_LINK`]).
    pub fn default_wan_link(
        mut self,
        latency_secs: f64,
        bytes_per_sec: f64,
    ) -> FederationBuilder {
        self.default_link = Some(WanLink {
            latency_secs,
            bytes_per_sec,
        });
        self
    }

    /// Replace the origin-registry uplink
    /// ([`super::wan::DEFAULT_ORIGIN_LINK`]).
    pub fn origin_wan_link(
        mut self,
        latency_secs: f64,
        bytes_per_sec: f64,
    ) -> FederationBuilder {
        self.origin_link = Some(WanLink {
            latency_secs,
            bytes_per_sec,
        });
        self
    }

    /// Replace the routing policy (default: [`DataLocality`]).
    pub fn routing(
        mut self,
        policy: Box<dyn RoutingPolicy>,
    ) -> FederationBuilder {
        self.routing = policy;
        self
    }

    /// Enable burst overflow: when the routed site's queue-wait
    /// estimate exceeds `secs`, eligible jobs spill to a compatible
    /// site whose estimated wait plus replication time beats staying.
    /// Must be positive ([`FederationError::BadOverflowThreshold`]).
    pub fn overflow_threshold_secs(mut self, secs: f64) -> FederationBuilder {
        self.overflow_threshold = Some(secs);
        self
    }

    /// Record telemetry for the whole federation: one shared recorder
    /// spans every member site plus the WAN replication lane.
    pub fn telemetry(mut self, enabled: bool) -> FederationBuilder {
        self.telemetry = enabled;
        self
    }

    /// Traffic seed federation storms inherit unless their spec sets
    /// its own.
    pub fn seed(mut self, seed: u64) -> FederationBuilder {
        self.seed = seed;
        self
    }

    /// Validate the declared knobs and wire the federation. Typed
    /// [`FederationError`] variants on conflict — never panics.
    pub fn build(self) -> Result<Federation, FederationError> {
        if self.sites.is_empty() {
            return Err(FederationError::NoSites);
        }
        for (i, (name, _)) in self.sites.iter().enumerate() {
            if self.sites[..i].iter().any(|(n, _)| n == name) {
                return Err(FederationError::DuplicateSite(name.clone()));
            }
        }
        if let Some(secs) = self.overflow_threshold {
            if secs.is_nan() || secs <= 0.0 {
                return Err(FederationError::BadOverflowThreshold { secs });
            }
        }

        let mut wan = WanModel::new();
        if let Some(link) = self.default_link {
            wan.set_default(link);
        }
        if let Some(link) = self.origin_link {
            wan.set_origin(link);
        }
        for (a, b, link) in &self.links {
            for site in [a, b] {
                if !self.sites.iter().any(|(n, _)| n == site) {
                    return Err(FederationError::UnknownLinkSite {
                        site: site.clone(),
                    });
                }
            }
            let bad_latency =
                link.latency_secs.is_nan() || link.latency_secs < 0.0;
            let bad_bw =
                link.bytes_per_sec.is_nan() || link.bytes_per_sec <= 0.0;
            if bad_latency || bad_bw {
                return Err(FederationError::BadWanLink {
                    a: a.clone(),
                    b: b.clone(),
                    latency_secs: link.latency_secs,
                    bytes_per_sec: link.bytes_per_sec,
                });
            }
            wan.set_link(a, b, *link);
        }

        let telemetry = Arc::new(Telemetry::new(self.telemetry));
        let mut entries = Vec::with_capacity(self.sites.len());
        for (name, builder) in self.sites {
            let site = builder
                .telemetry_recorder(Arc::clone(&telemetry))
                .build()
                .map_err(|source| FederationError::Site {
                    name: name.clone(),
                    source,
                })?;
            entries.push(SiteEntry::new(name, site));
        }

        let index = ReplicaIndex::new(
            entries.len(),
            Chunker::new(FEDERATION_CHUNK_TARGET_BYTES,
                         FEDERATION_CHUNK_SEED),
        );
        Ok(Federation {
            sites: entries,
            wan,
            routing: self.routing,
            overflow_threshold: self.overflow_threshold,
            index,
            telemetry,
            seed: self.seed,
        })
    }
}
