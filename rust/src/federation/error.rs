//! Typed errors for federation construction and storms.

use super::super::site::SiteError;

/// Everything that can go wrong building or driving a
/// [`super::Federation`]. Mirrors the [`SiteError`] idiom: builder
/// mistakes get their own variants with the offending values, member
/// site failures wrap the underlying [`SiteError`] with the site name
/// attached.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum FederationError {
    /// The builder was asked to build with no member sites.
    #[error("a federation needs at least one member site")]
    NoSites,

    /// Two member sites were declared under the same name.
    #[error("duplicate site name '{0}' — member names must be unique")]
    DuplicateSite(String),

    /// A WAN link names a site the federation does not contain.
    #[error("WAN link references unknown site '{site}'")]
    UnknownLinkSite {
        /// The name the link referenced.
        site: String,
    },

    /// A WAN link with a non-positive bandwidth or negative latency.
    #[error(
        "invalid WAN link {a} <-> {b}: latency {latency_secs}s, \
         bandwidth {bytes_per_sec} B/s (latency must be >= 0, \
         bandwidth > 0)"
    )]
    BadWanLink {
        /// First endpoint.
        a: String,
        /// Second endpoint.
        b: String,
        /// Declared one-way latency, seconds.
        latency_secs: f64,
        /// Declared bandwidth, bytes per second.
        bytes_per_sec: f64,
    },

    /// A non-positive burst-overflow threshold.
    #[error(
        "overflow threshold must be positive, got {secs}s \
         (use None to disable overflow)"
    )]
    BadOverflowThreshold {
        /// The rejected threshold, seconds.
        secs: f64,
    },

    /// Building one of the member sites failed.
    #[error("building member site '{name}' failed")]
    Site {
        /// The member site's declared name.
        name: String,
        /// The underlying builder error.
        #[source]
        source: SiteError,
    },

    /// A job stream replay referenced a job wider than every member
    /// site — nothing in the fleet could ever run it.
    #[error(
        "job {job} needs {width} nodes but the widest member site \
         has {widest} — regenerate the stream against the fleet"
    )]
    JobTooWide {
        /// Stream id of the offending job.
        job: u32,
        /// Requested node width.
        width: u32,
        /// Width of the widest member site.
        widest: u32,
    },

    /// Writing the Chrome trace artifact failed.
    #[error("writing federation trace to {path} failed")]
    Trace {
        /// Destination path.
        path: String,
        /// The underlying I/O error.
        #[source]
        source: std::io::Error,
    },
}
