//! Multi-site federation (DESIGN.md S27): one clock, many sites.
//!
//! One [`Site`] is one supercomputer; a research community's traffic
//! spans a *fleet* of heterogeneous centers. The [`Federation`] facade
//! composes N member sites — each with its own profile, partitions,
//! fabric, and scheduler — behind four cooperating mechanisms, all
//! replayed on one shared virtual clock:
//!
//! * **Cross-site registry replication** ([`ReplicaIndex`]): a
//!   federation-level CAS index of which site holds which chunks,
//!   priced over the [`WanModel`] with chunk-level dedup reusing the
//!   S25 CDC machinery — a file shared between images crosses the WAN
//!   once, and peers serve chunks ~15x faster than the origin
//!   registry.
//! * **Capability-aware routing** ([`RoutingPolicy`]): a job's
//!   extension requirements (GPU, MPI ABI, net transport — derived
//!   from its [`crate::launch::JobSpec`]) are matched against each
//!   site's advertised capability vectors; jobs no site can satisfy
//!   are rejected with a per-site reason instead of failing late.
//! * **Burst overflow**: when the routed site's queue-wait estimate
//!   crosses the threshold, eligible jobs spill to a compatible site
//!   whose estimated wait *plus replication time* beats staying —
//!   the replication cost is paid before the job may start and shows
//!   up as a `wan` span in the shared trace.
//! * **Cross-site accounting** ([`FederationReport`]): per-tenant
//!   wait/stretch across sites plus the federation-specific counters
//!   (overflow rate, replication bytes, WAN transfer time, routing
//!   rejections), exported as `BENCH_federation.json` by
//!   `benches/federation_burst.rs`.
//!
//! The storm pipeline is two-phase on the same timeline: a
//! [`SimKernel`] first replays every arrival — routing it, pricing
//! replication, and scheduling its *prepared* instant — then each
//! member site replays its share of the stream (arrivals stamped at
//! the prepared instant) through the ordinary
//! [`Site::run_storm`] scheduler. One shared [`Telemetry`] recorder
//! spans all of it, so the Chrome trace interleaves every site's
//! pull/stage/job spans with the federation's WAN lane.
//!
//! ```
//! use shifter_rs::federation::{Federation, FederationStorm};
//! use shifter_rs::{SiteBuilder, SystemProfile};
//!
//! let mut fed = Federation::builder()
//!     .site(
//!         "daint",
//!         SiteBuilder::new()
//!             .profile(SystemProfile::piz_daint())
//!             .nodes(8),
//!     )
//!     .site(
//!         "cluster",
//!         SiteBuilder::new()
//!             .profile(SystemProfile::linux_cluster())
//!             .nodes(8),
//!     )
//!     .build()
//!     .unwrap();
//! let report = fed
//!     .run_storm(&FederationStorm::new().tenants(2).jobs(8))
//!     .unwrap();
//! assert_eq!(report.records.len() + report.rejections.len(), 8);
//! ```

use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::metrics::Stats;
use crate::sim::{SimKernel, SimTime};
use crate::site::{Site, StormSpec};
use crate::telemetry::{SpanDraft, Telemetry};
use crate::tenancy::{TenantJob, TenantStats, TrafficModel};

pub mod error;
pub mod index;
pub mod report;
pub mod routing;
pub mod wan;

mod builder;

pub use builder::FederationBuilder;
pub use error::FederationError;
pub use index::{ReplicaIndex, ReplicationPlan};
pub use report::{
    FedJobRecord, FederationReport, RoutingRejection, SiteSummary,
};
pub use routing::{
    routing_policy_by_name, CapabilityFirst, DataLocality, LeastLoaded,
    PinnedHome, RandomPlacement, RoutingPolicy, SiteView,
};
pub use wan::{WanLink, WanModel};

/// Target chunk size of the federation replica index (4 MiB — the
/// same granularity the S25 CAS defaults to for cross-image dedup).
pub const FEDERATION_CHUNK_TARGET_BYTES: u64 = 4 << 20;

/// One member site plus the routing metadata the federation derives
/// from it once at build time.
pub(crate) struct SiteEntry {
    name: String,
    site: Site,
    /// Distinct extensions some partition advertises as available.
    available: BTreeSet<&'static str>,
    total_nodes: u32,
}

impl SiteEntry {
    pub(crate) fn new(name: String, site: Site) -> SiteEntry {
        let mut available = BTreeSet::new();
        for (_, caps) in site.capabilities() {
            for cap in caps {
                if cap.available {
                    available.insert(cap.extension);
                }
            }
        }
        let total_nodes = site.cluster().total_nodes();
        SiteEntry {
            name,
            site,
            available,
            total_nodes,
        }
    }
}

/// Per-site commitment timeline the router estimates queue waits
/// from: `(release time, width)` pairs of every routed job, walked in
/// release order until enough nodes free up. Deliberately coarser
/// than the member sites' real schedulers (no backfill, no launch
/// overhead) — it is an *estimator*, and both overflow baselines in
/// `federation_burst` use the same one.
struct SiteLoad {
    capacity: u32,
    commitments: Vec<(f64, u32)>,
}

impl SiteLoad {
    fn new(capacity: u32) -> SiteLoad {
        SiteLoad {
            capacity,
            commitments: Vec::new(),
        }
    }

    /// Estimated queue wait for a `width`-node job arriving at `now`.
    fn est_wait(&self, now: f64, width: u32) -> f64 {
        let need = width.min(self.capacity) as u64;
        let cap = self.capacity as u64;
        let mut active: Vec<(f64, u32)> = self
            .commitments
            .iter()
            .filter(|(end, _)| *end > now)
            .copied()
            .collect();
        active.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut used: u64 =
            active.iter().map(|(_, w)| *w as u64).sum::<u64>().min(cap);
        if cap - used >= need {
            return 0.0;
        }
        for (end, w) in &active {
            used = used.saturating_sub(*w as u64);
            if cap - used >= need {
                return end - now;
            }
        }
        0.0
    }

    fn commit(&mut self, end: f64, width: u32) {
        self.commitments.push((end, width));
    }

    fn prune(&mut self, now: f64) {
        self.commitments.retain(|(end, _)| *end > now);
    }
}

/// Events of the federation-level arrival replay.
enum FedEvent {
    /// Stream job `i` arrives at the federation front door.
    Arrival(usize),
    /// Stream job `i`'s image replication to `site` finished; the job
    /// enters that site's queue now.
    Prepared { job: usize, site: usize },
}

/// Where one job ended up, recorded during the arrival replay.
#[derive(Clone)]
struct Route {
    site: usize,
    overflowed: bool,
    prepared_secs: f64,
}

/// Describes a federation storm: either synthesized traffic (the
/// [`TrafficModel`] defaults, generated against the *narrowest*
/// member site so every job fits everywhere capability allows) or an
/// explicit replayed stream, plus an optional Chrome-trace export
/// path. The mirror of [`StormSpec`] at fleet scope.
#[derive(Debug, Clone, Default)]
pub struct FederationStorm {
    tenants: Option<u32>,
    jobs: Option<u32>,
    arrival_rate_per_min: Option<f64>,
    duration_secs: Option<f64>,
    mean_runtime_secs: Option<f64>,
    max_width: Option<u32>,
    seed: Option<u64>,
    traffic: Option<TrafficModel>,
    stream: Option<Vec<TenantJob>>,
    trace_path: Option<PathBuf>,
}

impl FederationStorm {
    /// A storm with the stock [`TrafficModel`] defaults (8 tenants,
    /// 64 jobs, 2.4 arrivals/min) and the federation's seed.
    pub fn new() -> FederationStorm {
        FederationStorm::default()
    }

    /// Number of simulated tenants.
    pub fn tenants(mut self, tenants: u32) -> FederationStorm {
        self.tenants = Some(tenants);
        self
    }

    /// Number of jobs to synthesize.
    pub fn jobs(mut self, jobs: u32) -> FederationStorm {
        self.jobs = Some(jobs);
        self
    }

    /// Aggregate Poisson arrival rate, jobs per minute.
    pub fn arrival_rate_per_min(mut self, rate: f64) -> FederationStorm {
        self.arrival_rate_per_min = Some(rate);
        self
    }

    /// Stop generating arrivals past this horizon (seconds).
    pub fn duration_secs(mut self, secs: f64) -> FederationStorm {
        self.duration_secs = Some(secs);
        self
    }

    /// Mean application runtime, seconds.
    pub fn mean_runtime_secs(mut self, secs: f64) -> FederationStorm {
        self.mean_runtime_secs = Some(secs);
        self
    }

    /// Cap on synthesized job widths (additionally clamped to the
    /// narrowest member site).
    pub fn max_width(mut self, width: u32) -> FederationStorm {
        self.max_width = Some(width);
        self
    }

    /// Traffic seed for this storm (default: the federation's seed).
    pub fn seed(mut self, seed: u64) -> FederationStorm {
        self.seed = Some(seed);
        self
    }

    /// Replace the whole synthesized [`TrafficModel`] (the scalar
    /// knobs above are ignored when set).
    pub fn traffic(mut self, traffic: TrafficModel) -> FederationStorm {
        self.traffic = Some(traffic);
        self
    }

    /// Replay an explicit job stream instead of synthesizing one —
    /// the form the benches use to route the *same* stream under two
    /// federation configurations.
    pub fn job_stream(mut self, jobs: Vec<TenantJob>) -> FederationStorm {
        self.stream = Some(jobs);
        self
    }

    /// Write the shared recorder's Chrome trace (every site's spans
    /// plus the WAN lane) to `path` after the storm.
    pub fn trace_path(
        mut self,
        path: impl AsRef<Path>,
    ) -> FederationStorm {
        self.trace_path = Some(path.as_ref().to_path_buf());
        self
    }
}

/// A fleet of heterogeneous [`Site`]s behind one storm entry point.
/// Built by [`FederationBuilder`]; see the [module docs](self) for
/// the architecture.
pub struct Federation {
    pub(crate) sites: Vec<SiteEntry>,
    pub(crate) wan: WanModel,
    pub(crate) routing: Box<dyn RoutingPolicy>,
    pub(crate) overflow_threshold: Option<f64>,
    pub(crate) index: ReplicaIndex,
    pub(crate) telemetry: Arc<Telemetry>,
    pub(crate) seed: u64,
}

impl Federation {
    /// Start declaring a federation.
    pub fn builder() -> FederationBuilder {
        FederationBuilder::new()
    }

    /// Member site names, in federation order.
    pub fn site_names(&self) -> Vec<&str> {
        self.sites.iter().map(|e| e.name.as_str()).collect()
    }

    /// Borrow a member site by name.
    pub fn site(&self, name: &str) -> Option<&Site> {
        self.sites
            .iter()
            .find(|e| e.name == name)
            .map(|e| &e.site)
    }

    /// The WAN topology.
    pub fn wan(&self) -> &WanModel {
        &self.wan
    }

    /// The cross-site replica index (which site holds which chunks).
    pub fn index(&self) -> &ReplicaIndex {
        &self.index
    }

    /// The shared telemetry recorder spanning every member site.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// The active routing policy's name.
    pub fn routing_policy(&self) -> &'static str {
        self.routing.name()
    }

    /// Sum of member-site node widths.
    pub fn total_nodes(&self) -> u32 {
        self.sites.iter().map(|e| e.total_nodes).sum()
    }

    /// Run a federation storm: replay every arrival on the shared
    /// virtual clock (route → maybe overflow → replicate → enqueue at
    /// the member site), then drain each member site's share of the
    /// stream through its own scheduler, and join the two timelines
    /// into one [`FederationReport`].
    ///
    /// The replica index persists across storms — a second storm sees
    /// warm replicas, exactly like a second pull sees a warm CAS.
    pub fn run_storm(
        &mut self,
        spec: &FederationStorm,
    ) -> Result<FederationReport, FederationError> {
        let jobs = self.resolve_stream(spec)?;
        let n = self.sites.len();

        // -- phase 1: arrival replay on the shared kernel -----------------
        let mut kernel: SimKernel<FedEvent> = SimKernel::new();
        for (i, job) in jobs.iter().enumerate() {
            kernel.schedule_at(
                SimTime::from_secs(job.arrival_secs),
                FedEvent::Arrival(i),
            );
        }

        let mut routes: Vec<Option<Route>> = vec![None; jobs.len()];
        let mut rejections: Vec<RoutingRejection> = Vec::new();
        let mut streams: Vec<Vec<TenantJob>> = vec![Vec::new(); n];
        let mut loads: Vec<SiteLoad> = self
            .sites
            .iter()
            .map(|e| SiteLoad::new(e.total_nodes))
            .collect();
        // (site, image) -> completion time of an in-flight replication,
        // so concurrent arrivals of one image coalesce onto one
        // transfer instead of double-paying the WAN
        let mut inflight: BTreeMap<(usize, String), f64> = BTreeMap::new();
        let mut overflows = 0usize;
        let mut peer_bytes = 0u64;
        let mut origin_bytes = 0u64;
        let mut replications = 0usize;
        let mut wan_transfer_secs = 0.0f64;

        while let Some((t, event)) = kernel.pop() {
            let now = t.as_secs_f64();
            match event {
                FedEvent::Arrival(i) => {
                    let job = &jobs[i];
                    for load in &mut loads {
                        load.prune(now);
                    }
                    let (views, reasons) = self.eligible_views(
                        job, now, &loads,
                    );
                    if views.is_empty() {
                        self.telemetry.count("federation.rejections", 1);
                        rejections.push(RoutingRejection {
                            id: job.id,
                            tenant: job.tenant.clone(),
                            image: job.spec.image.clone(),
                            reason: format!(
                                "no eligible site: {}",
                                reasons.join("; ")
                            ),
                        });
                        continue;
                    }
                    let pick = self.routing.choose(job, &views);
                    let chosen = views[pick].clone();
                    let mut dest = chosen.site;
                    let mut overflowed = false;
                    if let Some(threshold) = self.overflow_threshold {
                        if chosen.est_wait_secs > threshold
                            && views.len() > 1
                        {
                            let alt = Self::best_alternative(
                                &views, chosen.site,
                            );
                            if let Some(alt) = alt {
                                let spill_cost =
                                    alt.est_wait_secs + alt.wan_secs;
                                if spill_cost < chosen.est_wait_secs {
                                    dest = alt.site;
                                    overflowed = true;
                                }
                            }
                        }
                    }
                    if overflowed {
                        overflows += 1;
                        self.telemetry.count("federation.overflows", 1);
                    }
                    self.telemetry.count("federation.routed", 1);

                    // replicate (or coalesce onto an in-flight copy)
                    let key = (dest, job.spec.image.clone());
                    let ready = match inflight.get(&key) {
                        Some(&r) if r > now => r,
                        _ => {
                            let (secs, peer, origin) =
                                self.replicate(dest, &job.spec.image, now);
                            if peer + origin > 0 {
                                replications += 1;
                                peer_bytes += peer;
                                origin_bytes += origin;
                                wan_transfer_secs += secs;
                            }
                            let ready = now + secs;
                            inflight.insert(key, ready);
                            ready
                        }
                    };

                    // commit the estimator: the job should occupy
                    // [ready + est_wait, + runtime) at the destination
                    let est_start = ready
                        + loads[dest].est_wait(ready, job.spec.nodes);
                    loads[dest].commit(
                        est_start + job.runtime_secs,
                        job.spec.nodes,
                    );
                    routes[i] = Some(Route {
                        site: dest,
                        overflowed,
                        prepared_secs: ready,
                    });
                    kernel.schedule_at(
                        SimTime::from_secs(ready),
                        FedEvent::Prepared { job: i, site: dest },
                    );
                }
                FedEvent::Prepared { job, site } => {
                    let mut queued = jobs[job].clone();
                    queued.arrival_secs = now;
                    streams[site].push(queued);
                }
            }
        }

        // -- phase 2: member-site storms on the routed streams ------------
        let mut site_reports = Vec::with_capacity(n);
        for (idx, stream) in streams.iter().enumerate() {
            if stream.is_empty() {
                site_reports.push(None);
                continue;
            }
            let entry = &mut self.sites[idx];
            let report = entry
                .site
                .run_storm(&StormSpec::new().job_stream(stream.clone()))
                .map_err(|source| FederationError::Site {
                    name: entry.name.clone(),
                    source,
                })?;
            site_reports.push(Some(report));
        }

        // -- join the two timelines into the federation report ------------
        let report = self.assemble(
            &jobs,
            routes,
            rejections,
            &streams,
            &site_reports,
            overflows,
            peer_bytes,
            origin_bytes,
            replications,
            wan_transfer_secs,
        );
        if let Some(path) = &spec.trace_path {
            let trace = self.telemetry.chrome_trace_jsonl();
            std::fs::write(path, trace).map_err(|source| {
                FederationError::Trace {
                    path: path.display().to_string(),
                    source,
                }
            })?;
        }
        Ok(report)
    }

    // -- internals --------------------------------------------------------

    /// Synthesize or validate the storm's job stream.
    fn resolve_stream(
        &self,
        spec: &FederationStorm,
    ) -> Result<Vec<TenantJob>, FederationError> {
        let widest = self
            .sites
            .iter()
            .map(|e| e.total_nodes)
            .max()
            .unwrap_or(0);
        if let Some(stream) = &spec.stream {
            for job in stream {
                if job.spec.nodes > widest {
                    return Err(FederationError::JobTooWide {
                        job: job.id,
                        width: job.spec.nodes,
                        widest,
                    });
                }
            }
            return Ok(stream.clone());
        }
        let narrowest = match self.sites.iter().min_by_key(|e| e.total_nodes)
        {
            Some(entry) => &entry.site,
            None => unreachable!("builder rejects empty federations"),
        };
        let traffic = match &spec.traffic {
            Some(traffic) => traffic.clone(),
            None => {
                let defaults = TrafficModel::default();
                TrafficModel {
                    tenants: spec.tenants.unwrap_or(defaults.tenants),
                    jobs: spec.jobs.unwrap_or(defaults.jobs),
                    arrival_rate_per_min: spec
                        .arrival_rate_per_min
                        .unwrap_or(defaults.arrival_rate_per_min),
                    duration_secs: spec
                        .duration_secs
                        .unwrap_or(defaults.duration_secs),
                    mean_runtime_secs: spec
                        .mean_runtime_secs
                        .unwrap_or(defaults.mean_runtime_secs),
                    max_width: spec
                        .max_width
                        .unwrap_or(defaults.max_width),
                    seed: spec.seed.unwrap_or(self.seed),
                    ..defaults
                }
            }
        };
        // generate against the narrowest member's cluster: widths are
        // clamped so every synthesized job fits any capability-
        // compatible site
        Ok(traffic.generate(narrowest.cluster()))
    }

    /// Extensions the job's spec requires (the trigger set of the S22
    /// registry: GRES GPUs, the `--mpi` swap, `SHIFTER_NET=host`).
    fn requirements(job: &TenantJob) -> Vec<&'static str> {
        let mut reqs = Vec::new();
        if job.spec.gpus_per_node > 0 {
            reqs.push("gpu");
        }
        if job.spec.mpi {
            reqs.push("mpi");
        }
        let net = job.spec.env.get("SHIFTER_NET").map(String::as_str);
        if matches!(net, Some("host") | Some("native") | Some("1")) {
            reqs.push("net");
        }
        reqs
    }

    /// Build a [`SiteView`] per eligible site; for ineligible sites
    /// collect a human-readable reason instead.
    fn eligible_views(
        &mut self,
        job: &TenantJob,
        now: f64,
        loads: &[SiteLoad],
    ) -> (Vec<SiteView>, Vec<String>) {
        let reqs = Self::requirements(job);
        let names: Vec<String> =
            self.sites.iter().map(|e| e.name.clone()).collect();
        let manifest = match self.lookup_image(&job.spec.image) {
            Some(image) => self.index.manifest(&image),
            None => Vec::new(),
        };
        let mut views = Vec::new();
        let mut reasons = Vec::new();
        for (idx, entry) in self.sites.iter().enumerate() {
            if job.spec.nodes > entry.total_nodes {
                reasons.push(format!(
                    "{}: width {} > {} nodes",
                    entry.name, job.spec.nodes, entry.total_nodes
                ));
                continue;
            }
            let missing: Vec<&'static str> = reqs
                .iter()
                .copied()
                .filter(|r| !entry.available.contains(r))
                .collect();
            if !missing.is_empty() {
                reasons.push(format!(
                    "{}: no partition advertises {}",
                    entry.name,
                    missing.join("+")
                ));
                continue;
            }
            let plan =
                self.index.plan(idx, &manifest, &names, &self.wan);
            views.push(SiteView {
                site: idx,
                name: entry.name.clone(),
                total_nodes: entry.total_nodes,
                est_wait_secs: loads[idx].est_wait(now, job.spec.nodes),
                missing_bytes: plan.total_bytes(),
                wan_secs: plan.secs,
                capability_score: entry.available.len() as u32,
            });
        }
        (views, reasons)
    }

    /// The overflow fallback: the eligible site (≠ `exclude`) with the
    /// lowest estimated wait plus replication time.
    fn best_alternative(
        views: &[SiteView],
        exclude: usize,
    ) -> Option<&SiteView> {
        views
            .iter()
            .filter(|v| v.site != exclude)
            .min_by(|a, b| {
                (a.est_wait_secs + a.wan_secs)
                    .total_cmp(&(b.est_wait_secs + b.wan_secs))
                    .then(a.site.cmp(&b.site))
            })
    }

    /// Move the image's missing chunks to `site`, charge the WAN, emit
    /// the telemetry span, and commit the index. Returns
    /// `(secs, peer_bytes, origin_bytes)` — all zero when the site
    /// already holds a full replica.
    fn replicate(
        &mut self,
        site: usize,
        reference: &str,
        now: f64,
    ) -> (f64, u64, u64) {
        let Some(image) = self.lookup_image(reference) else {
            // unknown images fail at the member site with the site's
            // own registry error; nothing to replicate
            return (0.0, 0, 0);
        };
        let names: Vec<String> =
            self.sites.iter().map(|e| e.name.clone()).collect();
        let manifest = self.index.manifest(&image);
        let plan = self.index.plan(site, &manifest, &names, &self.wan);
        if plan.total_bytes() == 0 {
            return (0.0, 0, 0);
        }
        self.index.commit(site, &manifest);
        self.telemetry.span(SpanDraft {
            parent: None,
            category: "wan",
            name: &format!(
                "replicate {} -> {}",
                reference, self.sites[site].name
            ),
            track: "wan",
            start: SimTime::from_secs(now),
            dur_secs: plan.secs,
        });
        self.telemetry.count("federation.replications", 1);
        self.telemetry.count("federation.peer_bytes", plan.peer_bytes);
        self.telemetry
            .count("federation.origin_bytes", plan.origin_bytes);
        self.telemetry.observe("federation.wan_secs", plan.secs);
        (plan.secs, plan.peer_bytes, plan.origin_bytes)
    }

    fn lookup_image(&self, reference: &str) -> Option<crate::image::Image> {
        // the origin catalog is shared: any member's registry view of
        // the reference works, and the first site always exists
        self.sites
            .first()
            .and_then(|e| e.site.registry().lookup(reference).ok())
            .cloned()
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        &self,
        jobs: &[TenantJob],
        routes: Vec<Option<Route>>,
        rejections: Vec<RoutingRejection>,
        streams: &[Vec<TenantJob>],
        site_reports: &[Option<crate::tenancy::TenancyReport>],
        overflows: usize,
        peer_bytes: u64,
        origin_bytes: u64,
        replications: usize,
        wan_transfer_secs: f64,
    ) -> FederationReport {
        // site-side records by stream id
        let mut by_id: BTreeMap<u32, (usize, &crate::tenancy::JobRecord)> =
            BTreeMap::new();
        for (idx, report) in site_reports.iter().enumerate() {
            if let Some(report) = report {
                for record in &report.records {
                    by_id.insert(record.id, (idx, record));
                }
            }
        }

        let mut records = Vec::new();
        for (i, job) in jobs.iter().enumerate() {
            let Some(route) = &routes[i] else { continue };
            let Some((site_idx, site_record)) = by_id.get(&job.id) else {
                continue;
            };
            debug_assert_eq!(*site_idx, route.site);
            let wan_wait = route.prepared_secs - job.arrival_secs;
            records.push(FedJobRecord {
                id: job.id,
                tenant: job.tenant.clone(),
                tenant_idx: job.tenant_idx,
                image: job.spec.image.clone(),
                width: job.spec.nodes,
                arrival_secs: job.arrival_secs,
                site: self.sites[route.site].name.clone(),
                overflowed: route.overflowed,
                wan_wait_secs: wan_wait,
                site_wait_secs: site_record.wait_secs,
                total_wait_secs: wan_wait + site_record.wait_secs,
                service_secs: site_record.service_secs,
                error: site_record.error.clone(),
            });
        }

        // per-site rollups
        let mut sites = Vec::new();
        for (idx, entry) in self.sites.iter().enumerate() {
            let overflow_jobs = records
                .iter()
                .filter(|r| r.overflowed && r.site == entry.name)
                .count();
            let (completed, makespan, utilization, wait) =
                match &site_reports[idx] {
                    Some(report) => (
                        report.completed(),
                        report.makespan_secs,
                        report.utilization(),
                        report.wait_stats(),
                    ),
                    None => (0, 0.0, 0.0, None),
                };
            sites.push(SiteSummary {
                name: entry.name.clone(),
                total_nodes: entry.total_nodes,
                jobs: streams[idx].len(),
                overflow_jobs,
                completed,
                makespan_secs: makespan,
                utilization,
                wait,
            });
        }

        // per-tenant aggregates over completed jobs, end-to-end waits
        let mut by_tenant: BTreeMap<String, Vec<&FedJobRecord>> =
            BTreeMap::new();
        for record in records.iter().filter(|r| r.ok()) {
            by_tenant
                .entry(record.tenant.clone())
                .or_default()
                .push(record);
        }
        let tenants = by_tenant
            .into_iter()
            .map(|(tenant, recs)| {
                let waits: Vec<f64> =
                    recs.iter().map(|r| r.total_wait_secs).collect();
                let stretches: Vec<f64> = recs
                    .iter()
                    .filter_map(|r| r.stretch())
                    .collect();
                TenantStats {
                    tenant,
                    jobs: recs.len(),
                    node_secs: recs
                        .iter()
                        .map(|r| r.width as f64 * r.service_secs)
                        .sum(),
                    wait: Stats::from_samples(&waits),
                    stretch: if stretches.is_empty() {
                        Stats::from_samples(&[0.0])
                    } else {
                        Stats::from_samples(&stretches)
                    },
                }
            })
            .collect();

        let makespan_secs = site_reports
            .iter()
            .flatten()
            .map(|r| r.makespan_secs)
            .fold(0.0f64, f64::max);

        FederationReport {
            routing: self.routing.name().to_string(),
            overflow_threshold_secs: self.overflow_threshold,
            records,
            rejections,
            sites,
            tenants,
            overflows,
            peer_bytes,
            origin_bytes,
            replications,
            wan_transfer_secs,
            makespan_secs,
        }
    }
}
