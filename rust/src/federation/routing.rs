//! Capability-aware routing: the pluggable [`RoutingPolicy`] trait and
//! its builtin policies.
//!
//! Mirrors the [`crate::tenancy::SchedulingPolicy`] idiom: a policy is
//! a small strategy object the federation consults per job, builtins
//! are zero-config, and [`routing_policy_by_name`] maps CLI names to
//! boxed instances. A policy only ever sees *eligible* sites — the
//! federation has already removed sites whose capability vectors miss
//! a requirement or that are narrower than the job — so every policy
//! reduces to a deterministic tie-broken argmin over [`SiteView`]s.

use crate::tenancy::TenantJob;
use crate::util::prng::Rng;

/// What the routing policy knows about one eligible site at decision
/// time. All estimates are computed at the job's federation arrival
/// instant.
#[derive(Debug, Clone)]
pub struct SiteView {
    /// Federation site index (stable across the storm).
    pub site: usize,
    /// The site's declared name.
    pub name: String,
    /// Total node width of the site.
    pub total_nodes: u32,
    /// Estimated queue wait for this job's width, seconds, from the
    /// federation's commitment-timeline load estimator.
    pub est_wait_secs: f64,
    /// Bytes of the job's image the site is missing (0 = full replica
    /// already on site).
    pub missing_bytes: u64,
    /// Estimated replication time if routed here, seconds (0 when
    /// nothing is missing).
    pub wan_secs: f64,
    /// Distinct host extensions the site advertises as available
    /// (gpu/mpi/net) — a coarse "how capable" score beyond the job's
    /// hard requirements.
    pub capability_score: u32,
}

/// Strategy for picking one site out of the eligible set.
///
/// `choose` receives the job and a non-empty slice of eligible
/// [`SiteView`]s (federation order) and returns an *index into that
/// slice*. Policies may keep state (e.g. a seeded RNG) — the
/// federation owns the box mutably.
pub trait RoutingPolicy {
    /// Stable policy name (`data-locality`, `least-loaded`, ...).
    fn name(&self) -> &'static str;

    /// Pick a site: an index into `eligible` (non-empty).
    fn choose(&mut self, job: &TenantJob, eligible: &[SiteView]) -> usize;
}

/// Route to the site missing the fewest bytes of the job's image —
/// replicas concentrate where images already live, minimizing WAN
/// traffic. Ties break on estimated wait, then site index.
#[derive(Debug, Clone, Copy, Default)]
pub struct DataLocality;

impl RoutingPolicy for DataLocality {
    fn name(&self) -> &'static str {
        "data-locality"
    }

    fn choose(&mut self, _job: &TenantJob, eligible: &[SiteView]) -> usize {
        argmin(eligible, |v| {
            (v.missing_bytes as f64, v.est_wait_secs, v.site as f64)
        })
    }
}

/// Route to the site with the lowest estimated queue wait. Ties break
/// on missing bytes, then site index.
#[derive(Debug, Clone, Copy, Default)]
pub struct LeastLoaded;

impl RoutingPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn choose(&mut self, _job: &TenantJob, eligible: &[SiteView]) -> usize {
        argmin(eligible, |v| {
            (v.est_wait_secs, v.missing_bytes as f64, v.site as f64)
        })
    }
}

/// Route to the most capable site (highest advertised-extension
/// score) — the XaaS-style "strongest match" placement. Ties break on
/// estimated wait, then site index.
#[derive(Debug, Clone, Copy, Default)]
pub struct CapabilityFirst;

impl RoutingPolicy for CapabilityFirst {
    fn name(&self) -> &'static str {
        "capability-first"
    }

    fn choose(&mut self, _job: &TenantJob, eligible: &[SiteView]) -> usize {
        argmin(eligible, |v| {
            (
                -(v.capability_score as f64),
                v.est_wait_secs,
                v.site as f64,
            )
        })
    }
}

/// Uniform seeded random placement over the eligible set — the
/// scatter-everything baseline `federation_burst` compares
/// [`DataLocality`] against. Deterministic per seed.
#[derive(Debug, Clone)]
pub struct RandomPlacement {
    rng: Rng,
}

impl RandomPlacement {
    /// A placement stream seeded with `seed`.
    pub fn new(seed: u64) -> RandomPlacement {
        RandomPlacement {
            rng: Rng::from_tags(&["federation-random", &seed.to_string()]),
        }
    }
}

impl RoutingPolicy for RandomPlacement {
    fn name(&self) -> &'static str {
        "random"
    }

    fn choose(&mut self, _job: &TenantJob, eligible: &[SiteView]) -> usize {
        self.rng.below(eligible.len() as u64) as usize
    }
}

/// Every tenant has a home site (`tenant_idx % n_sites`) and all of
/// its jobs go there — the no-federation baseline `federation_burst`
/// measures burst overflow against. Falls back to the first eligible
/// site when the home is ineligible for a particular job.
#[derive(Debug, Clone, Copy)]
pub struct PinnedHome {
    sites: usize,
}

impl PinnedHome {
    /// Pin tenants round-robin across `sites` member sites.
    pub fn new(sites: usize) -> PinnedHome {
        PinnedHome { sites: sites.max(1) }
    }
}

impl RoutingPolicy for PinnedHome {
    fn name(&self) -> &'static str {
        "pinned-home"
    }

    fn choose(&mut self, job: &TenantJob, eligible: &[SiteView]) -> usize {
        let home = job.tenant_idx as usize % self.sites;
        eligible
            .iter()
            .position(|v| v.site == home)
            .unwrap_or(0)
    }
}

/// Resolve a CLI policy name to a boxed policy (`data-locality`,
/// `least-loaded`, `capability-first`, `random`, `pinned-home`).
/// `seed` feeds [`RandomPlacement`]; `sites` feeds [`PinnedHome`].
pub fn routing_policy_by_name(
    name: &str,
    seed: u64,
    sites: usize,
) -> Option<Box<dyn RoutingPolicy>> {
    match name {
        "data-locality" => Some(Box::new(DataLocality)),
        "least-loaded" => Some(Box::new(LeastLoaded)),
        "capability-first" => Some(Box::new(CapabilityFirst)),
        "random" => Some(Box::new(RandomPlacement::new(seed))),
        "pinned-home" => Some(Box::new(PinnedHome::new(sites))),
        _ => None,
    }
}

/// Deterministic argmin over a float key triple: lexicographic
/// `total_cmp`, so NaN never flips an ordering and ties always break
/// the same way.
fn argmin<F>(views: &[SiteView], key: F) -> usize
where
    F: Fn(&SiteView) -> (f64, f64, f64),
{
    let mut best = 0;
    let mut best_key = key(&views[0]);
    for (idx, view) in views.iter().enumerate().skip(1) {
        let k = key(view);
        let ord = k
            .0
            .total_cmp(&best_key.0)
            .then(k.1.total_cmp(&best_key.1))
            .then(k.2.total_cmp(&best_key.2));
        if ord == std::cmp::Ordering::Less {
            best = idx;
            best_key = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::launch::JobSpec;
    use crate::tenancy::JobClass;

    fn job(tenant_idx: u32) -> TenantJob {
        TenantJob {
            id: 0,
            tenant: format!("tenant-{tenant_idx:02}"),
            tenant_idx,
            arrival_secs: 0.0,
            runtime_secs: 60.0,
            class: JobClass::Cpu,
            spec: JobSpec::new("ubuntu:xenial", &["true"], 1),
        }
    }

    fn view(site: usize, wait: f64, missing: u64, score: u32) -> SiteView {
        SiteView {
            site,
            name: format!("site-{site}"),
            total_nodes: 64,
            est_wait_secs: wait,
            missing_bytes: missing,
            wan_secs: 0.0,
            capability_score: score,
        }
    }

    #[test]
    fn builtins_pick_their_dimension() {
        let views = vec![
            view(0, 10.0, 0, 2),
            view(1, 0.0, 500, 2),
            view(2, 5.0, 200, 3),
        ];
        let j = job(0);
        assert_eq!(DataLocality.choose(&j, &views), 0);
        assert_eq!(LeastLoaded.choose(&j, &views), 1);
        assert_eq!(CapabilityFirst.choose(&j, &views), 2);
    }

    #[test]
    fn pinned_home_follows_tenant_and_falls_back() {
        let mut pinned = PinnedHome::new(3);
        let views = vec![view(0, 0.0, 0, 2), view(2, 0.0, 0, 2)];
        assert_eq!(pinned.choose(&job(2), &views), 1); // home = 2
        assert_eq!(pinned.choose(&job(1), &views), 0); // home 1 missing
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let views = vec![view(0, 0.0, 0, 2), view(1, 0.0, 0, 2)];
        let picks = |seed| {
            let mut p = RandomPlacement::new(seed);
            (0..16).map(|_| p.choose(&job(0), &views)).collect::<Vec<_>>()
        };
        assert_eq!(picks(7), picks(7));
        assert_ne!(picks(7), picks(8));
    }

    #[test]
    fn names_resolve() {
        for name in [
            "data-locality",
            "least-loaded",
            "capability-first",
            "random",
            "pinned-home",
        ] {
            let policy = routing_policy_by_name(name, 7, 3);
            assert_eq!(policy.map(|p| p.name()), Some(name));
        }
        assert!(routing_policy_by_name("nope", 7, 3).is_none());
    }
}
