//! Cross-site tenancy accounting: the [`FederationReport`] a
//! federation storm produces, exported as `BENCH_federation.json`.

use crate::metrics::{Stats, Table};
use crate::tenancy::TenantStats;
use crate::util::json::Json;

/// One job's cross-site outcome: where it was routed, what the WAN
/// charged before it could start, and how the member site's scheduler
/// treated it.
#[derive(Debug, Clone)]
pub struct FedJobRecord {
    /// Stream id, unique across the federation storm.
    pub id: u32,
    /// Owning tenant name.
    pub tenant: String,
    /// Owning tenant index.
    pub tenant_idx: u32,
    /// Image reference the job launched.
    pub image: String,
    /// Node width.
    pub width: u32,
    /// Federation arrival time (storm seconds).
    pub arrival_secs: f64,
    /// Name of the site the job ran on.
    pub site: String,
    /// The job left the site the routing policy first chose because
    /// that site's queue-wait estimate crossed the burst threshold.
    pub overflowed: bool,
    /// Replication delay paid before the job reached the site's queue
    /// (0.0 when the site already held a full replica).
    pub wan_wait_secs: f64,
    /// Queue wait inside the member site.
    pub site_wait_secs: f64,
    /// End-to-end wait: `wan_wait_secs + site_wait_secs`.
    pub total_wait_secs: f64,
    /// Occupancy duration on the site (0.0 when the job failed).
    pub service_secs: f64,
    /// Whole-job failure reported by the member site.
    pub error: Option<String>,
}

impl FedJobRecord {
    /// True when the job launched.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// Cross-site slowdown `(total_wait + service) / service`; `None`
    /// for failed jobs.
    pub fn stretch(&self) -> Option<f64> {
        (self.ok() && self.service_secs > 0.0).then(|| {
            (self.total_wait_secs + self.service_secs) / self.service_secs
        })
    }
}

/// A job the router could not place anywhere, and why.
#[derive(Debug, Clone)]
pub struct RoutingRejection {
    /// Stream id of the rejected job.
    pub id: u32,
    /// Owning tenant name.
    pub tenant: String,
    /// Image reference the job asked for.
    pub image: String,
    /// Per-site explanation of why no site qualified.
    pub reason: String,
}

/// Per-member-site rollup inside a [`FederationReport`].
#[derive(Debug, Clone)]
pub struct SiteSummary {
    /// The site's declared name.
    pub name: String,
    /// Total node width.
    pub total_nodes: u32,
    /// Jobs routed to the site (including overflow arrivals).
    pub jobs: usize,
    /// Jobs that arrived via burst overflow.
    pub overflow_jobs: usize,
    /// Jobs the site completed.
    pub completed: usize,
    /// The site storm's makespan, seconds.
    pub makespan_secs: f64,
    /// The site storm's node utilization in `[0, 1]`.
    pub utilization: f64,
    /// Site-local queue-wait distribution (None when no job ran).
    pub wait: Option<Stats>,
}

/// What a federation storm produces: per-job cross-site records,
/// per-site and per-tenant rollups, and the federation-specific
/// counters (overflow rate, WAN replication traffic, routing
/// rejections).
#[derive(Debug, Clone)]
pub struct FederationReport {
    /// Routing policy that placed the stream.
    pub routing: String,
    /// Burst-overflow threshold, seconds (`None` = overflow disabled).
    pub overflow_threshold_secs: Option<f64>,
    /// Per-job outcomes, in submission order.
    pub records: Vec<FedJobRecord>,
    /// Jobs no site could accept, with reasons.
    pub rejections: Vec<RoutingRejection>,
    /// Per-site rollups, in federation order.
    pub sites: Vec<SiteSummary>,
    /// Per-tenant aggregates over completed jobs (wait = end-to-end
    /// wait including WAN), in tenant-name order.
    pub tenants: Vec<TenantStats>,
    /// Jobs that spilled to a non-home site via burst overflow.
    pub overflows: usize,
    /// Replication bytes moved over site-pair WAN links.
    pub peer_bytes: u64,
    /// Replication bytes pulled from the origin registry.
    pub origin_bytes: u64,
    /// Image replications performed (coalesced arrivals share one).
    pub replications: usize,
    /// Total WAN transfer time charged across all replications.
    pub wan_transfer_secs: f64,
    /// Time from storm start until the last member site drained.
    pub makespan_secs: f64,
}

impl FederationReport {
    /// Fraction of routed jobs that overflowed (0.0 when nothing was
    /// routed).
    pub fn overflow_rate(&self) -> f64 {
        if self.records.is_empty() {
            0.0
        } else {
            self.overflows as f64 / self.records.len() as f64
        }
    }

    /// Jobs that completed on their site.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.ok()).count()
    }

    /// End-to-end wait distribution over completed jobs (`None` when
    /// nothing completed).
    pub fn total_wait_stats(&self) -> Option<Stats> {
        let waits: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.total_wait_secs)
            .collect();
        (!waits.is_empty()).then(|| Stats::from_samples(&waits))
    }

    /// WAN replication-delay distribution over routed jobs (`None`
    /// when nothing was routed).
    pub fn wan_wait_stats(&self) -> Option<Stats> {
        let waits: Vec<f64> =
            self.records.iter().map(|r| r.wan_wait_secs).collect();
        (!waits.is_empty()).then(|| Stats::from_samples(&waits))
    }

    /// Total replication bytes over any wire.
    pub fn replication_bytes(&self) -> u64 {
        self.peer_bytes + self.origin_bytes
    }

    /// The artifact document (stable key order via the ordered
    /// [`Json`] writer): federation counters, per-site and per-tenant
    /// rollups, and aggregate wait distributions — per-job records are
    /// summarized, not dumped, to keep `BENCH_federation.json` small.
    pub fn to_json(&self) -> Json {
        let sites = self
            .sites
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(&s.name)),
                    ("total_nodes", Json::num(s.total_nodes as f64)),
                    ("jobs", Json::num(s.jobs as f64)),
                    ("overflow_jobs", Json::num(s.overflow_jobs as f64)),
                    ("completed", Json::num(s.completed as f64)),
                    ("makespan_secs", Json::num(s.makespan_secs)),
                    ("utilization", Json::num(s.utilization)),
                    (
                        "wait",
                        match &s.wait {
                            Some(stats) => stats.to_json(),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        let tenants = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(&t.tenant)),
                    ("jobs", Json::num(t.jobs as f64)),
                    ("node_secs", Json::num(t.node_secs)),
                    ("wait", t.wait.to_json()),
                    ("stretch", t.stretch.to_json()),
                ])
            })
            .collect();
        let rejections = self
            .rejections
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("tenant", Json::str(&r.tenant)),
                    ("image", Json::str(&r.image)),
                    ("reason", Json::str(&r.reason)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("routing", Json::str(&self.routing)),
            (
                "overflow_threshold_secs",
                match self.overflow_threshold_secs {
                    Some(secs) => Json::num(secs),
                    None => Json::Null,
                },
            ),
            ("jobs", Json::num(self.records.len() as f64)),
            ("completed", Json::num(self.completed() as f64)),
            ("overflows", Json::num(self.overflows as f64)),
            ("overflow_rate", Json::num(self.overflow_rate())),
            ("rejected", Json::num(self.rejections.len() as f64)),
            ("peer_bytes", Json::num(self.peer_bytes as f64)),
            ("origin_bytes", Json::num(self.origin_bytes as f64)),
            (
                "replication_bytes",
                Json::num(self.replication_bytes() as f64),
            ),
            ("replications", Json::num(self.replications as f64)),
            ("wan_transfer_secs", Json::num(self.wan_transfer_secs)),
            ("makespan_secs", Json::num(self.makespan_secs)),
            (
                "total_wait",
                match self.total_wait_stats() {
                    Some(stats) => stats.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "wan_wait",
                match self.wan_wait_stats() {
                    Some(stats) => stats.to_json(),
                    None => Json::Null,
                },
            ),
            ("sites", Json::Arr(sites)),
            ("tenants", Json::Arr(tenants)),
            ("rejections", Json::Arr(rejections)),
        ])
    }

    /// Human-readable rollup: one row per member site plus the
    /// federation counters.
    pub fn render(&self) -> String {
        let mut table = Table::new(
            &format!(
                "federation storm — routing {}, {} jobs, {} overflow, \
                 {} rejected",
                self.routing,
                self.records.len(),
                self.overflows,
                self.rejections.len()
            ),
            &[
                "site", "nodes", "jobs", "overflow", "completed",
                "p50 wait", "p99 wait", "util",
            ],
        );
        for s in &self.sites {
            let (p50, p99) = match &s.wait {
                Some(w) => {
                    (format!("{:.1}s", w.p50), format!("{:.1}s", w.p99))
                }
                None => ("-".to_string(), "-".to_string()),
            };
            table.row(&[
                s.name.clone(),
                s.total_nodes.to_string(),
                s.jobs.to_string(),
                s.overflow_jobs.to_string(),
                s.completed.to_string(),
                p50,
                p99,
                format!("{:.0}%", s.utilization * 100.0),
            ]);
        }
        let mut out = table.render();
        out.push_str(&format!(
            "replication: {} peer B + {} origin B over {} transfers, \
             {:.1}s WAN time; makespan {:.0}s\n",
            self.peer_bytes,
            self.origin_bytes,
            self.replications,
            self.wan_transfer_secs,
            self.makespan_secs
        ));
        out
    }
}
