//! The federation-level replica index: which site holds which chunks,
//! and what a replication to a given site would cost.
//!
//! Reuses the S25 CDC machinery ([`Chunker::synthetic_chunks`]) so two
//! images sharing layers — or sharing files below layer granularity —
//! dedup across the WAN exactly as they dedup inside one site's CAS:
//! a replication moves only the chunks the destination is missing,
//! each fetched from the cheapest peer that already holds it, falling
//! through to the origin registry only for chunks no peer has.

use std::collections::{BTreeMap, BTreeSet};

use crate::distrib::Chunker;
use crate::image::Image;
use crate::vfs::VNode;

use super::wan::WanModel;

/// What one replication would move and how long it would take.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicationPlan {
    /// Bytes fetched from peer sites over site-pair WAN links.
    pub peer_bytes: u64,
    /// Bytes fetched from the origin registry (no peer held them).
    pub origin_bytes: u64,
    /// Missing chunks the transfer moves.
    pub chunks: usize,
    /// Transfer time: sources stream in parallel, so the max over the
    /// per-source link times (0.0 when nothing is missing).
    pub secs: f64,
    /// Per-peer-source byte counts, by federation site index.
    pub sources: Vec<(usize, u64)>,
}

impl ReplicationPlan {
    /// Total bytes the plan moves over any wire.
    pub fn total_bytes(&self) -> u64 {
        self.peer_bytes + self.origin_bytes
    }
}

/// Chunk-level CAS index across every member site.
#[derive(Debug, Clone)]
pub struct ReplicaIndex {
    chunker: Chunker,
    /// Per-site set of held chunk digests.
    sites: Vec<BTreeSet<u64>>,
    /// Per-image chunk manifest cache: `(digest, length)` pairs,
    /// deduplicated within the image.
    manifests: BTreeMap<String, Vec<(u64, u64)>>,
}

impl ReplicaIndex {
    /// An empty index over `sites` member sites — no site holds
    /// anything until the first replication commits.
    pub fn new(sites: usize, chunker: Chunker) -> ReplicaIndex {
        ReplicaIndex {
            chunker,
            sites: vec![BTreeSet::new(); sites],
            manifests: BTreeMap::new(),
        }
    }

    /// The image's chunk manifest: every file of every layer cut into
    /// content-defined chunks keyed by the file's content digest (the
    /// same derivation the S25 CAS uses), deduplicated by chunk digest
    /// — a file shared between layers or *images* yields identical
    /// chunks and is moved across the WAN once. Cached per reference;
    /// deterministic per chunker seed.
    pub fn manifest(&mut self, image: &Image) -> Vec<(u64, u64)> {
        let reference = image.reference.canonical();
        if let Some(cached) = self.manifests.get(&reference) {
            return cached.clone();
        }
        let mut chunks: BTreeMap<u64, u64> = BTreeMap::new();
        for layer in &image.layers {
            let files = layer.tree.walk("/").unwrap_or_default();
            for (_, node) in files {
                let VNode::File { size, digest, .. } = node else {
                    continue;
                };
                // chunk the transfer representation of the file
                let compressed = (size as f64 * 0.5) as u64;
                if compressed == 0 {
                    continue;
                }
                for chunk in
                    self.chunker.synthetic_chunks(digest, compressed)
                {
                    chunks.insert(chunk.digest, chunk.length);
                }
            }
        }
        let manifest: Vec<(u64, u64)> = chunks.into_iter().collect();
        self.manifests.insert(reference, manifest.clone());
        manifest
    }

    /// Bytes of `manifest` the site is missing.
    pub fn missing_bytes(
        &self,
        site: usize,
        manifest: &[(u64, u64)],
    ) -> u64 {
        manifest
            .iter()
            .filter(|(digest, _)| !self.sites[site].contains(digest))
            .map(|(_, length)| *length)
            .sum()
    }

    /// Price moving `manifest`'s missing chunks to `site`: each missing
    /// chunk comes from the peer with the cheapest per-byte link (ties
    /// break on latency, then site index — deterministic), or the
    /// origin registry when no peer holds it. Sources stream in
    /// parallel, so the plan's `secs` is the slowest source's time.
    pub fn plan(
        &self,
        site: usize,
        manifest: &[(u64, u64)],
        names: &[String],
        wan: &WanModel,
    ) -> ReplicationPlan {
        let mut per_source: BTreeMap<usize, u64> = BTreeMap::new();
        let mut plan = ReplicationPlan::default();
        for (digest, length) in manifest {
            if self.sites[site].contains(digest) {
                continue;
            }
            plan.chunks += 1;
            let holder = self.cheapest_holder(site, *digest, names, wan);
            match holder {
                Some(source) => {
                    plan.peer_bytes += length;
                    *per_source.entry(source).or_insert(0) += length;
                }
                None => plan.origin_bytes += length,
            }
        }
        let mut secs = wan.origin().transfer_secs(plan.origin_bytes);
        for (&source, &bytes) in &per_source {
            let link = wan.link(&names[site], &names[source]);
            let t = link.transfer_secs(bytes);
            if t > secs {
                secs = t;
            }
        }
        plan.secs = secs;
        plan.sources = per_source.into_iter().collect();
        plan
    }

    /// Record that `site` now holds every chunk of `manifest`.
    pub fn commit(&mut self, site: usize, manifest: &[(u64, u64)]) {
        for (digest, _) in manifest {
            self.sites[site].insert(*digest);
        }
    }

    /// Distinct chunks the site currently holds.
    pub fn held_chunks(&self, site: usize) -> usize {
        self.sites[site].len()
    }

    fn cheapest_holder(
        &self,
        dest: usize,
        digest: u64,
        names: &[String],
        wan: &WanModel,
    ) -> Option<usize> {
        let mut best: Option<(f64, f64, usize)> = None;
        for (idx, held) in self.sites.iter().enumerate() {
            if idx == dest || !held.contains(&digest) {
                continue;
            }
            let link = wan.link(&names[dest], &names[idx]);
            // cheaper per byte first, then lower latency, then index
            let key = (-link.bytes_per_sec, link.latency_secs, idx);
            let better = match &best {
                None => true,
                Some((bw, lat, i)) => {
                    match key.0.total_cmp(bw).then(key.1.total_cmp(lat)) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Greater => false,
                        std::cmp::Ordering::Equal => idx < *i,
                    }
                }
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, idx)| idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::Registry;

    fn chunker() -> Chunker {
        Chunker::new(4 << 20, 0xC0FFEE)
    }

    fn image(reference: &str) -> Image {
        Registry::dockerhub()
            .lookup(reference)
            .expect("catalog image")
            .clone()
    }

    #[test]
    fn first_copy_comes_from_origin_then_peers_serve() {
        let names = vec!["a".to_string(), "b".to_string()];
        let wan = WanModel::new();
        let mut index = ReplicaIndex::new(2, chunker());
        let manifest = index.manifest(&image("ubuntu:xenial"));
        assert!(!manifest.is_empty());

        let cold = index.plan(0, &manifest, &names, &wan);
        assert_eq!(cold.peer_bytes, 0);
        assert!(cold.origin_bytes > 0);
        index.commit(0, &manifest);

        // same image to the second site: all bytes now come from site 0
        let warm = index.plan(1, &manifest, &names, &wan);
        assert_eq!(warm.origin_bytes, 0);
        assert_eq!(warm.peer_bytes, cold.origin_bytes);
        assert_eq!(warm.sources, vec![(0, warm.peer_bytes)]);
        // the peer link is far faster than the origin uplink
        assert!(warm.secs < cold.secs);

        // and once committed, nothing is missing
        index.commit(1, &manifest);
        assert_eq!(index.missing_bytes(1, &manifest), 0);
        assert_eq!(
            index.plan(1, &manifest, &names, &wan),
            ReplicationPlan::default()
        );
    }

    #[test]
    fn shared_layers_dedup_across_images() {
        let names = vec!["a".to_string(), "b".to_string()];
        let wan = WanModel::new();
        let mut index = ReplicaIndex::new(2, chunker());
        // both images are built on the same Ubuntu xenial base files
        let m_a = index.manifest(&image("ubuntu:xenial"));
        let m_b = index.manifest(&image("nvidia/cuda-image:8.0"));
        index.commit(0, &m_a);
        index.commit(1, &m_a);
        let full: u64 = m_b.iter().map(|(_, l)| l).sum();
        let plan = index.plan(1, &m_b, &names, &wan);
        assert!(
            plan.total_bytes() < full,
            "shared chunks should not move again ({} vs {})",
            plan.total_bytes(),
            full
        );
    }
}
