//! Host system profiles (DESIGN.md S12): the three machines of §V.A with
//! their exact software environments and hardware configurations. The
//! runtime's decisions (what to mount, which ABI to match, which fabric
//! the MPI reaches) depend only on this inventory.

pub mod modules;

pub use modules::{daint_catalog, ModuleDef, ModuleError, ModuleSystem};

use crate::fabric::FabricKind;
use crate::gpu::{GpuModel, NvidiaDriver};
use crate::mpi::MpiImpl;
use crate::netfab::NetAbi;
use crate::pfs::LustreFs;
use crate::vfs::{VNode, VirtualFs};

/// One compute node's hardware.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub cpu_model: &'static str,
    pub cores: u32,
    pub ram_gb: u32,
    pub gpus: Vec<GpuModel>,
}

impl NodeSpec {
    pub fn driver(&self, version: (u32, u32)) -> Option<NvidiaDriver> {
        if self.gpus.is_empty() {
            None
        } else {
            Some(NvidiaDriver::new(version, self.gpus.clone()))
        }
    }
}

/// A complete host system.
#[derive(Debug, Clone)]
pub struct SystemProfile {
    pub name: &'static str,
    pub os: &'static str,
    pub kernel: &'static str,
    /// CUDA toolkit installed on the host (None = no CUDA).
    pub cuda_toolkit: Option<(u32, u32)>,
    /// NVIDIA driver version.
    pub driver_version: Option<(u32, u32)>,
    pub host_mpi: MpiImpl,
    pub fabric: FabricKind,
    pub nodes: Vec<NodeSpec>,
    pub pfs: Option<LustreFs>,
    /// Filesystem prefix where the host MPI lives.
    pub mpi_prefix: &'static str,
    /// Directory holding the NVIDIA driver libraries.
    pub gpu_lib_dir: &'static str,
    /// Directory holding nvidia-smi.
    pub gpu_bin_dir: &'static str,
}

impl SystemProfile {
    /// Lenovo W540 mobile workstation (§V.A "Workstation Laptop"):
    /// i7-4700MQ, 8 GB, Quadro K110M, CentOS 7 (3.10.0), CUDA 8.0,
    /// MPICH 3.2.
    pub fn laptop() -> SystemProfile {
        SystemProfile {
            name: "Laptop",
            os: "CentOS 7",
            kernel: "3.10.0",
            cuda_toolkit: Some((8, 0)),
            driver_version: Some((375, 26)),
            host_mpi: MpiImpl::mpich_3_2_host(),
            fabric: FabricKind::Loopback,
            nodes: vec![NodeSpec {
                cpu_model: "Intel Core i7-4700MQ",
                cores: 4,
                ram_gb: 8,
                gpus: vec![GpuModel::quadro_k110m()],
            }],
            pfs: None,
            mpi_prefix: "/usr/lib64/mpich",
            gpu_lib_dir: "/usr/lib64/nvidia",
            gpu_bin_dir: "/usr/bin",
        }
    }

    /// Two-node heterogeneous Linux Cluster (§V.A): E5-1650v3 / E5-2650v4,
    /// 64 GB each, one K40m + one K80 per node, EDR InfiniBand, Scientific
    /// Linux 7.2 (3.10.0), CUDA 7.5, MVAPICH2 (2.1 native for Table III).
    pub fn linux_cluster() -> SystemProfile {
        SystemProfile {
            name: "Linux Cluster",
            os: "Scientific Linux 7.2",
            kernel: "3.10.0",
            // Host *toolkit* is CUDA 7.5 (§V.A) but the installed driver is
            // newer — required, since the paper runs CUDA-8-built container
            // images (TensorFlow 1.0) on this system via PTX forward compat.
            cuda_toolkit: Some((7, 5)),
            driver_version: Some((367, 48)),
            host_mpi: MpiImpl::mvapich2_2_1_host_ib(),
            fabric: FabricKind::InfinibandEdr,
            nodes: vec![
                NodeSpec {
                    cpu_model: "Intel Xeon E5-1650v3",
                    cores: 6,
                    ram_gb: 64,
                    gpus: vec![GpuModel::tesla_k40m(), GpuModel::tesla_k80()],
                },
                NodeSpec {
                    cpu_model: "Intel Xeon E5-2650v4",
                    cores: 12,
                    ram_gb: 64,
                    gpus: vec![GpuModel::tesla_k40m(), GpuModel::tesla_k80()],
                },
            ],
            pfs: Some(LustreFs::linux_cluster()),
            mpi_prefix: "/opt/mvapich2-2.1",
            gpu_lib_dir: "/usr/lib64/nvidia",
            gpu_bin_dir: "/usr/bin",
        }
    }

    /// Piz Daint, hybrid Cray XC50/XC40 (§V.A): E5-2690v3 + P100 per
    /// hybrid node, Aries dragonfly, CLE 6.0 (3.12.60), CUDA 8.0,
    /// Cray MPT 7.5.0. We model 384 hybrid nodes — enough for the
    /// largest (3072-rank) Pynamic job at 12 ranks/node.
    pub fn piz_daint() -> SystemProfile {
        let node = NodeSpec {
            cpu_model: "Intel Xeon E5-2690v3",
            cores: 12,
            ram_gb: 64,
            gpus: vec![GpuModel::tesla_p100()],
        };
        SystemProfile {
            name: "Piz Daint",
            os: "Cray Linux Environment 6.0 UP02",
            kernel: "3.12.60",
            cuda_toolkit: Some((8, 0)),
            driver_version: Some((375, 66)),
            host_mpi: MpiImpl::cray_mpt_7_5_host(),
            fabric: FabricKind::CrayAries,
            nodes: vec![node; 384],
            pfs: Some(LustreFs::piz_daint()),
            mpi_prefix: "/opt/cray/pe/mpt/7.5.0/gni/mpich-gnu/5.1",
            gpu_lib_dir: "/opt/cray/nvidia/default/lib64",
            gpu_bin_dir: "/opt/cray/nvidia/default/bin",
        }
    }

    pub fn node_count(&self) -> u32 {
        self.nodes.len() as u32
    }

    pub fn ranks_per_node(&self) -> u32 {
        self.nodes[0].cores
    }

    /// Driver instance for node `i`.
    pub fn driver(&self, node: usize) -> Option<NvidiaDriver> {
        self.nodes
            .get(node)
            .and_then(|n| n.driver(self.driver_version?))
    }

    /// Does the host satisfy §IV.A's GPU-support prerequisites?
    pub fn gpu_capable(&self) -> bool {
        self.driver(0).map(|d| d.uvm_loaded).unwrap_or(false)
    }

    /// The host root filesystem: site directories, driver libraries,
    /// NVIDIA binaries, the vendor MPI with its dependencies — everything
    /// the Shifter runtime may bind-mount into containers.
    pub fn host_fs(&self) -> VirtualFs {
        // The host tree is a static literal layout: every path below is
        // distinct by construction, so a VfsError here is a programming
        // error in this function — panic explicitly with the path.
        fn add(fs: &mut VirtualFs, path: &str, bytes: u64, digest: u64) {
            if let Err(e) = fs.add_file(path, bytes, digest) {
                unreachable!("host fs construction is static: {path}: {e}");
            }
        }
        fn mkdir(fs: &mut VirtualFs, path: &str) {
            if let Err(e) = fs.mkdir_p(path) {
                unreachable!("host fs construction is static: {path}: {e}");
            }
        }
        fn insert(fs: &mut VirtualFs, path: &str, node: VNode) {
            if let Err(e) = fs.insert(path, node) {
                unreachable!("host fs construction is static: {path}: {e}");
            }
        }

        let mut fs = VirtualFs::new();
        add(&mut fs, "/etc/os-release", 300, 0x05);
        mkdir(&mut fs, "/scratch");
        mkdir(&mut fs, "/home");
        mkdir(&mut fs, "/var/tmp");

        // NVIDIA driver stack
        if let (Some(dv), Some(node)) = (self.driver_version, self.nodes.first())
        {
            if !node.gpus.is_empty() {
                let driver = NvidiaDriver::new(dv, node.gpus.clone());
                for lib in driver.library_files() {
                    add(
                        &mut fs,
                        &format!("{}/{lib}", self.gpu_lib_dir),
                        8_000_000,
                        0x10 ^ lib.len() as u64,
                    );
                }
                for bin in crate::gpu::DRIVER_BINARIES {
                    insert(
                        &mut fs,
                        &format!("{}/{bin}", self.gpu_bin_dir),
                        VNode::exe(450_000, 0x20),
                    );
                }
                let mut id = 0;
                for g in &node.gpus {
                    for _ in 0..g.chips {
                        insert(
                            &mut fs,
                            &format!("/dev/nvidia{id}"),
                            VNode::Device {
                                major: 195,
                                minor: id,
                            },
                        );
                        id += 1;
                    }
                }
                insert(
                    &mut fs,
                    "/dev/nvidiactl",
                    VNode::Device { major: 195, minor: 255 },
                );
                insert(
                    &mut fs,
                    "/dev/nvidia-uvm",
                    VNode::Device { major: 243, minor: 0 },
                );
            }
        }

        // host MPI: frontend libs + transport dependencies + config
        for lib in self.host_mpi.frontend_libraries() {
            add(
                &mut fs,
                &format!("{}/lib/{lib}", self.mpi_prefix),
                6_000_000,
                0x30 ^ lib.len() as u64,
            );
        }
        for dep in self.mpi_dependency_libs() {
            add(&mut fs, &dep, 1_500_000, 0x40 ^ dep.len() as u64);
        }
        for cfg in self.mpi_config_paths() {
            add(&mut fs, &cfg, 2_000, 0x50);
        }

        // specialized-network transport stack (netfab): user-space
        // transport libraries plus the fabric device files NetworkSupport
        // grafts. Some transport libraries double as MPI dependencies
        // (libugni on Aries, libibverbs on the cluster) — keep the node
        // the MPI section already added.
        for lib in self.net_transport_libs() {
            if !fs.exists(&lib) {
                add(&mut fs, &lib, 900_000, 0x60 ^ lib.len() as u64);
            }
        }
        for (i, dev) in self.net_device_files().iter().enumerate() {
            if dev.ends_with("hugepages") {
                mkdir(&mut fs, dev);
            } else if !fs.exists(dev) {
                let major = if dev.contains("kgni") { 249 } else { 231 };
                insert(&mut fs, dev, VNode::Device { major, minor: i as u32 });
            }
        }
        fs
    }

    /// User-space transport libraries of the host fabric (the netfab
    /// analog of [`SystemProfile::mpi_dependency_libs`]): the uGNI/DMAPP
    /// stack on Cray Aries, the verbs/RDMA stack on InfiniBand.
    pub fn net_transport_libs(&self) -> Vec<String> {
        match self.fabric {
            FabricKind::InfinibandEdr => vec![
                "/usr/lib64/libibverbs.so.1".to_string(),
                "/usr/lib64/librdmacm.so.1".to_string(),
                "/usr/lib64/libmlx5.so.1".to_string(),
            ],
            FabricKind::CrayAries => vec![
                "/opt/cray/ugni/default/lib64/libugni.so.0".to_string(),
                "/opt/cray/dmapp/default/lib64/libdmapp.so.1".to_string(),
                "/opt/cray/xpmem/default/lib64/libxpmem.so.0".to_string(),
            ],
            FabricKind::Loopback => vec![],
        }
    }

    /// Fabric device files the transport libraries open: `/dev/kgni0` +
    /// `/dev/hugepages` on Aries, the `/dev/infiniband/*` nodes on
    /// InfiniBand.
    pub fn net_device_files(&self) -> Vec<String> {
        match self.fabric {
            FabricKind::InfinibandEdr => vec![
                "/dev/infiniband/uverbs0".to_string(),
                "/dev/infiniband/rdma_cm".to_string(),
            ],
            FabricKind::CrayAries => vec![
                "/dev/kgni0".to_string(),
                "/dev/hugepages".to_string(),
            ],
            FabricKind::Loopback => vec![],
        }
    }

    /// The host's transport ABI (the netfab analog of the host MPI's
    /// libtool string); None on fabric-less hosts.
    pub fn net_abi(&self) -> Option<NetAbi> {
        match self.fabric {
            FabricKind::InfinibandEdr => Some(NetAbi::new("verbs", 17)),
            FabricKind::CrayAries => Some(NetAbi::new("gni", 5)),
            FabricKind::Loopback => None,
        }
    }

    /// Host-specific shared libraries the vendor MPI depends on (§IV.B:
    /// "the full paths to the host's shared libraries upon which the host
    /// MPI libraries depend").
    pub fn mpi_dependency_libs(&self) -> Vec<String> {
        match self.fabric {
            FabricKind::InfinibandEdr => vec![
                "/usr/lib64/libibverbs.so.1".to_string(),
                "/usr/lib64/librdmacm.so.1".to_string(),
                "/usr/lib64/libibumad.so.3".to_string(),
            ],
            FabricKind::CrayAries => vec![
                "/opt/cray/ugni/default/lib64/libugni.so.0".to_string(),
                "/opt/cray/xpmem/default/lib64/libxpmem.so.0".to_string(),
                "/opt/cray/alps/default/lib64/libalpslli.so.0".to_string(),
                "/opt/cray/pe/pmi/default/lib64/libpmi.so.0".to_string(),
                "/opt/cray/wlm_detect/default/lib64/libwlm_detect.so.0"
                    .to_string(),
            ],
            FabricKind::Loopback => vec![],
        }
    }

    /// Config files/folders the host MPI needs (§IV.B third config item).
    pub fn mpi_config_paths(&self) -> Vec<String> {
        match self.fabric {
            FabricKind::InfinibandEdr => {
                vec!["/etc/libibverbs.d/mlx5.driver".to_string()]
            }
            FabricKind::CrayAries => {
                vec!["/etc/opt/cray/wlm_detect/active_wlm".to_string()]
            }
            FabricKind::Loopback => vec![],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper_inventory() {
        let lap = SystemProfile::laptop();
        assert_eq!(lap.cuda_toolkit, Some((8, 0)));
        assert_eq!(lap.host_mpi.version_string(), "MPICH 3.2.0");
        assert_eq!(lap.nodes.len(), 1);
        assert_eq!(lap.nodes[0].gpus[0].name, "Quadro K110M");

        let cl = SystemProfile::linux_cluster();
        assert_eq!(cl.cuda_toolkit, Some((7, 5)));
        assert_eq!(cl.fabric, FabricKind::InfinibandEdr);
        assert_eq!(cl.nodes.len(), 2);
        assert_eq!(cl.nodes[0].gpus.len(), 2); // K40m + K80
        assert_ne!(cl.nodes[0].cpu_model, cl.nodes[1].cpu_model);

        let pd = SystemProfile::piz_daint();
        assert_eq!(pd.kernel, "3.12.60");
        assert_eq!(pd.fabric, FabricKind::CrayAries);
        assert_eq!(pd.host_mpi.version_string(), "Cray MPT 7.5.0");
        assert_eq!(pd.nodes[0].gpus[0].name, "Tesla P100");
        assert!(pd.node_count() * pd.ranks_per_node() >= 3072);
    }

    #[test]
    fn gpu_capability() {
        assert!(SystemProfile::laptop().gpu_capable());
        assert!(SystemProfile::linux_cluster().gpu_capable());
        assert!(SystemProfile::piz_daint().gpu_capable());
    }

    #[test]
    fn host_fs_has_driver_and_mpi() {
        let pd = SystemProfile::piz_daint();
        let fs = pd.host_fs();
        assert!(fs.exists(
            "/opt/cray/nvidia/default/lib64/libcuda.so.375.66"
        ));
        assert!(fs.exists("/opt/cray/nvidia/default/bin/nvidia-smi"));
        assert!(fs.exists(&format!(
            "{}/lib/libmpi.so.12",
            pd.mpi_prefix
        )));
        assert!(fs.exists("/opt/cray/ugni/default/lib64/libugni.so.0"));
        assert!(fs.exists("/dev/nvidia0"));
        assert!(fs.exists("/dev/nvidia-uvm"));
    }

    #[test]
    fn net_inventory_matches_fabric() {
        let pd = SystemProfile::piz_daint();
        assert_eq!(pd.net_abi().unwrap().abi_string(), "gni:5");
        let fs = pd.host_fs();
        assert!(fs.exists("/opt/cray/dmapp/default/lib64/libdmapp.so.1"));
        assert!(fs.exists("/dev/kgni0"));
        assert!(fs.is_dir("/dev/hugepages"));

        let cl = SystemProfile::linux_cluster();
        assert_eq!(cl.net_abi().unwrap().abi_string(), "verbs:17");
        let fs = cl.host_fs();
        assert!(fs.exists("/usr/lib64/libmlx5.so.1"));
        assert!(fs.exists("/dev/infiniband/uverbs0"));
        assert!(fs.exists("/dev/infiniband/rdma_cm"));

        let lap = SystemProfile::laptop();
        assert!(lap.net_abi().is_none());
        assert!(lap.net_transport_libs().is_empty());
        assert!(lap.net_device_files().is_empty());
    }

    #[test]
    fn cluster_exposes_three_cuda_devices_per_node() {
        let cl = SystemProfile::linux_cluster();
        let d = cl.driver(0).unwrap();
        assert_eq!(d.cuda_device_count(), 3); // K40m + 2x K80 chips
        let fs = cl.host_fs();
        assert!(fs.exists("/dev/nvidia0"));
        assert!(fs.exists("/dev/nvidia1"));
        assert!(fs.exists("/dev/nvidia2"));
    }

    #[test]
    fn cluster_driver_runs_cuda8_containers_via_ptx_compat() {
        // the cluster's host toolkit is 7.5, but its 367 driver runs the
        // CUDA-8-built TensorFlow container (PTX forward compatibility)
        let cl = SystemProfile::linux_cluster();
        assert_eq!(cl.cuda_toolkit, Some((7, 5)));
        assert!(cl.driver(0).unwrap().supports_cuda((8, 0)));
        assert!(cl.driver(0).unwrap().supports_cuda((7, 5)));
    }
}
