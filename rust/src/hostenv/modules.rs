//! Environment Modules — "The software environment on Piz Daint is the
//! Cray Linux Environment 6.0 UP02 using *Environment Modules* to provide
//! access to compilers, tools, and applications" (§V.A).
//!
//! `module load cudatoolkit/8.0` style environment mutation: each module
//! prepends paths and sets variables; `module unload` reverses it. The
//! native (non-container) baseline runs of the evaluation are launched
//! from environments assembled this way.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModuleDef {
    pub name: &'static str,
    pub version: &'static str,
    /// (variable, value) pairs set on load.
    pub setenv: Vec<(&'static str, &'static str)>,
    /// (variable, path) prepended on load (PATH-style).
    pub prepend: Vec<(&'static str, &'static str)>,
    /// Modules that conflict (auto-unloaded on load).
    pub conflicts: Vec<&'static str>,
}

impl ModuleDef {
    pub fn full_name(&self) -> String {
        format!("{}/{}", self.name, self.version)
    }
}

/// Piz Daint's module catalog (the subset the evaluation touches).
pub fn daint_catalog() -> Vec<ModuleDef> {
    vec![
        ModuleDef {
            name: "PrgEnv-cray",
            version: "6.0.4",
            setenv: vec![("PE_ENV", "CRAY")],
            prepend: vec![("PATH", "/opt/cray/pe/craype/default/bin")],
            conflicts: vec!["PrgEnv-gnu"],
        },
        ModuleDef {
            name: "PrgEnv-gnu",
            version: "6.0.4",
            setenv: vec![("PE_ENV", "GNU")],
            prepend: vec![("PATH", "/opt/gcc/default/bin")],
            conflicts: vec!["PrgEnv-cray"],
        },
        ModuleDef {
            name: "cudatoolkit",
            version: "8.0.44",
            setenv: vec![("CUDATOOLKIT_HOME", "/opt/nvidia/cudatoolkit8.0")],
            prepend: vec![
                ("PATH", "/opt/nvidia/cudatoolkit8.0/bin"),
                ("LD_LIBRARY_PATH", "/opt/nvidia/cudatoolkit8.0/lib64"),
            ],
            conflicts: vec![],
        },
        ModuleDef {
            name: "cray-mpich",
            version: "7.5.0",
            setenv: vec![("MPICH_DIR", "/opt/cray/pe/mpt/7.5.0/gni/mpich-gnu/5.1")],
            prepend: vec![(
                "LD_LIBRARY_PATH",
                "/opt/cray/pe/mpt/7.5.0/gni/mpich-gnu/5.1/lib",
            )],
            conflicts: vec![],
        },
        ModuleDef {
            name: "daint-gpu",
            version: "1.0",
            setenv: vec![("CRAY_ACCEL_TARGET", "nvidia60")],
            prepend: vec![],
            conflicts: vec!["daint-mc"],
        },
    ]
}

#[derive(Debug, thiserror::Error, PartialEq)]
#[non_exhaustive]
pub enum ModuleError {
    #[error("module not found: {0}")]
    NotFound(String),
}

/// A module shell session.
#[derive(Debug, Default)]
pub struct ModuleSystem {
    catalog: Vec<ModuleDef>,
    loaded: Vec<String>,
    pub env: BTreeMap<String, String>,
}

impl ModuleSystem {
    pub fn new(catalog: Vec<ModuleDef>) -> ModuleSystem {
        ModuleSystem {
            catalog,
            loaded: Vec::new(),
            env: BTreeMap::new(),
        }
    }

    pub fn avail(&self) -> Vec<String> {
        self.catalog.iter().map(|m| m.full_name()).collect()
    }

    pub fn loaded(&self) -> &[String] {
        &self.loaded
    }

    fn find(&self, name: &str) -> Option<ModuleDef> {
        self.catalog
            .iter()
            .find(|m| m.full_name() == name || m.name == name)
            .cloned()
    }

    /// `module load <name>` — applies setenv/prepend, unloads conflicts.
    pub fn load(&mut self, name: &str) -> Result<(), ModuleError> {
        let def = self
            .find(name)
            .ok_or_else(|| ModuleError::NotFound(name.to_string()))?;
        for conflict in &def.conflicts {
            let loaded_conflict = self
                .loaded
                .iter()
                .find(|l| l.starts_with(&format!("{conflict}/")))
                .cloned();
            if let Some(c) = loaded_conflict {
                self.unload(&c)?;
            }
        }
        if self.loaded.contains(&def.full_name()) {
            return Ok(());
        }
        for (k, v) in &def.setenv {
            self.env.insert(k.to_string(), v.to_string());
        }
        for (k, p) in &def.prepend {
            let old = self.env.get(*k).cloned().unwrap_or_default();
            let new = if old.is_empty() {
                p.to_string()
            } else {
                format!("{p}:{old}")
            };
            self.env.insert(k.to_string(), new);
        }
        self.loaded.push(def.full_name());
        Ok(())
    }

    /// `module unload <name>` — removes the module's contributions.
    pub fn unload(&mut self, name: &str) -> Result<(), ModuleError> {
        let def = self
            .find(name)
            .ok_or_else(|| ModuleError::NotFound(name.to_string()))?;
        if let Some(pos) = self.loaded.iter().position(|l| *l == def.full_name()) {
            self.loaded.remove(pos);
            for (k, _) in &def.setenv {
                self.env.remove(*k);
            }
            for (k, p) in &def.prepend {
                if let Some(val) = self.env.get_mut(*k) {
                    let parts: Vec<&str> =
                        val.split(':').filter(|s| s != p).collect();
                    *val = parts.join(":");
                    if val.is_empty() {
                        self.env.remove(*k);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn daint() -> ModuleSystem {
        ModuleSystem::new(daint_catalog())
    }

    #[test]
    fn load_sets_environment() {
        let mut m = daint();
        m.load("cudatoolkit").unwrap();
        assert_eq!(
            m.env.get("CUDATOOLKIT_HOME").unwrap(),
            "/opt/nvidia/cudatoolkit8.0"
        );
        assert!(m
            .env
            .get("LD_LIBRARY_PATH")
            .unwrap()
            .contains("cudatoolkit8.0/lib64"));
        assert_eq!(m.loaded(), ["cudatoolkit/8.0.44"]);
    }

    #[test]
    fn prepend_stacks_in_order() {
        let mut m = daint();
        m.load("cudatoolkit").unwrap();
        m.load("cray-mpich").unwrap();
        let ld = m.env.get("LD_LIBRARY_PATH").unwrap();
        // the most recently loaded module is first
        assert!(ld.starts_with("/opt/cray/pe/mpt"));
        assert!(ld.contains("cudatoolkit8.0"));
    }

    #[test]
    fn conflicts_swap_programming_environments() {
        let mut m = daint();
        m.load("PrgEnv-cray").unwrap();
        assert_eq!(m.env.get("PE_ENV").unwrap(), "CRAY");
        m.load("PrgEnv-gnu").unwrap();
        assert_eq!(m.env.get("PE_ENV").unwrap(), "GNU");
        assert_eq!(m.loaded(), ["PrgEnv-gnu/6.0.4"]);
    }

    #[test]
    fn unload_reverses_load() {
        let mut m = daint();
        m.load("cudatoolkit").unwrap();
        m.unload("cudatoolkit").unwrap();
        assert!(m.env.get("CUDATOOLKIT_HOME").is_none());
        assert!(m.env.get("LD_LIBRARY_PATH").is_none());
        assert!(m.loaded().is_empty());
    }

    #[test]
    fn double_load_is_idempotent() {
        let mut m = daint();
        m.load("cudatoolkit").unwrap();
        m.load("cudatoolkit").unwrap();
        assert_eq!(m.loaded().len(), 1);
        let ld = m.env.get("LD_LIBRARY_PATH").unwrap();
        assert_eq!(ld.matches("cudatoolkit8.0").count(), 1);
    }

    #[test]
    fn unknown_module_reported() {
        let mut m = daint();
        assert_eq!(
            m.load("tensorflow"),
            Err(ModuleError::NotFound("tensorflow".into()))
        );
    }

    #[test]
    fn module_env_vs_container_env_contrast() {
        // the paper's point: natively you assemble the environment with
        // modules; the container carries its own and needs none of this
        let mut m = daint();
        m.load("PrgEnv-cray").unwrap();
        m.load("cudatoolkit").unwrap();
        m.load("cray-mpich").unwrap();
        assert_eq!(m.loaded().len(), 3);
        let image = crate::image::builder::tensorflow_image();
        let cenv = image.env_map();
        // container env is self-contained: no module-provided paths
        assert!(cenv.get("CUDA_HOME").unwrap().contains("/usr/local/cuda"));
        assert!(!cenv
            .values()
            .any(|v| v.contains("/opt/nvidia/cudatoolkit8.0")));
    }
}
