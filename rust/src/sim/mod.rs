//! Virtual-time discrete-event kernel (DESIGN.md S24): one clock for
//! launch, gateway, and tenancy.
//!
//! Every layer of the simulator used to keep its own notion of time —
//! the gateway shards ticked a private `f64` clock, the launch
//! orchestrator executed slots on a real `std::thread::scope` worker
//! pool (so storm width was bounded by host threads), and the tenancy
//! scheduler hand-rolled a min-of-next-event loop. This module extracts
//! the one mechanism they all share:
//!
//! * [`SimTime`] — a totally ordered newtype over simulated seconds.
//!   `f64` under the hood (every cost model in the repo produces `f64`
//!   durations), but `Eq`/`Ord` via `f64::total_cmp`, so it can key a
//!   binary heap and sort deterministically.
//! * [`SimClock`] — the single monotonic time authority. Clocks only
//!   move forward; [`SimClock::advance_to`] debug-asserts monotonicity.
//! * [`SimKernel`] — a deterministic discrete-event queue: a binary
//!   heap of events keyed by `(SimTime, seq)`, where `seq` is the
//!   schedule order. Two events at the same instant pop in the order
//!   they were scheduled, so a trace replays bit-identically regardless
//!   of host thread count or `--test-threads` setting.
//!
//! The clients (in migration order): the launch scheduler's per-node
//! slot execution (slot-start/slot-done events replaced its thread
//! pool), the gateway shard drain path (exact `pending_secs`-sized
//! ticks instead of a magic `1e9`-second drain), and the
//! `FairShareScheduler` pass loop (arrival/completion events). See
//! `benches/sim_scale.rs` for the payoff: a 100k-node, million-job,
//! week-long trace in seconds of wall time.
//!
//! ```
//! use shifter_rs::sim::{SimKernel, SimTime};
//!
//! let mut kernel: SimKernel<&str> = SimKernel::new();
//! kernel.schedule_at(SimTime::from_secs(2.0), "b");
//! kernel.schedule_at(SimTime::from_secs(1.0), "a");
//! kernel.schedule_at(SimTime::from_secs(2.0), "c"); // same instant: FIFO
//! let order: Vec<&str> = std::iter::from_fn(|| kernel.pop())
//!     .map(|(_, e)| e)
//!     .collect();
//! assert_eq!(order, ["a", "b", "c"]);
//! assert_eq!(kernel.now(), SimTime::from_secs(2.0));
//! ```

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::{Add, AddAssign, Sub};

/// An instant in simulated time, in seconds since the start of the
/// simulation.
///
/// A newtype over `f64` so public signatures stop passing ad-hoc
/// second counts ("is this a duration or a timestamp?"), with total
/// ordering (`f64::total_cmp`) so instants can key heaps and sorts.
/// Durations stay plain `f64` seconds: `SimTime - SimTime` yields a
/// `f64` duration, `SimTime + f64` shifts an instant.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimTime(f64);

impl SimTime {
    /// The start of simulated time.
    pub const ZERO: SimTime = SimTime(0.0);

    /// An instant `secs` seconds after time zero.
    pub fn from_secs(secs: f64) -> SimTime {
        debug_assert!(secs.is_finite(), "non-finite SimTime: {secs}");
        SimTime(secs)
    }

    /// Seconds since time zero — the report/JSON compatibility
    /// accessor every `*_secs` consumer migrates to.
    pub fn as_secs_f64(self) -> f64 {
        self.0
    }

    /// The later of two instants.
    pub fn max(self, other: SimTime) -> SimTime {
        if other > self {
            other
        } else {
            self
        }
    }
}

impl PartialEq for SimTime {
    fn eq(&self, other: &SimTime) -> bool {
        self.0.total_cmp(&other.0) == Ordering::Equal
    }
}

impl Eq for SimTime {}

impl PartialOrd for SimTime {
    fn partial_cmp(&self, other: &SimTime) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SimTime {
    fn cmp(&self, other: &SimTime) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl Add<f64> for SimTime {
    type Output = SimTime;
    /// Shift an instant forward by a duration in seconds.
    fn add(self, secs: f64) -> SimTime {
        SimTime::from_secs(self.0 + secs)
    }
}

impl AddAssign<f64> for SimTime {
    fn add_assign(&mut self, secs: f64) {
        *self = *self + secs;
    }
}

impl Sub<f64> for SimTime {
    type Output = SimTime;
    /// Shift an instant backward by a duration in seconds.
    fn sub(self, secs: f64) -> SimTime {
        SimTime::from_secs(self.0 - secs)
    }
}

impl Sub<SimTime> for SimTime {
    type Output = f64;
    /// The signed duration between two instants, in seconds.
    fn sub(self, other: SimTime) -> f64 {
        self.0 - other.0
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}s", self.0)
    }
}

/// The single monotonic time authority of a simulation. Layers that
/// own a clock (the gateway pull queues, the event kernel) hold one of
/// these instead of a raw `f64`; time only moves forward.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimClock {
    now: SimTime,
}

impl SimClock {
    /// A clock at time zero.
    pub fn new() -> SimClock {
        SimClock::default()
    }

    /// The current instant.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance the clock by `secs` seconds.
    pub fn advance(&mut self, secs: f64) -> SimTime {
        debug_assert!(secs >= 0.0, "clocks only move forward: {secs}");
        self.now += secs;
        self.now
    }

    /// Advance the clock to `t`; a target at or before `now` is a
    /// no-op (clocks never move backward).
    pub fn advance_to(&mut self, t: SimTime) -> SimTime {
        if t > self.now {
            self.now = t;
        }
        self.now
    }
}

/// One queued event: the payload plus its `(time, seq)` heap key.
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Scheduled<E>) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl<E> Eq for Scheduled<E> {}

impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Scheduled<E>) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Scheduled<E> {
    /// Inverted so `BinaryHeap` (a max-heap) pops the *earliest*
    /// `(time, seq)` first.
    fn cmp(&self, other: &Scheduled<E>) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event kernel: schedule events at absolute
/// instants (or relative delays), pop them in `(SimTime, seq)` order,
/// and the kernel's [`SimClock`] advances to each popped event's time.
///
/// `seq` is the scheduling order, so simultaneous events pop FIFO —
/// the property that makes traces bit-identical across runs. See the
/// [module docs](self) for an example.
pub struct SimKernel<E> {
    clock: SimClock,
    next_seq: u64,
    heap: BinaryHeap<Scheduled<E>>,
}

impl<E> Default for SimKernel<E> {
    fn default() -> SimKernel<E> {
        SimKernel::new()
    }
}

impl<E> SimKernel<E> {
    /// An empty kernel at time zero.
    pub fn new() -> SimKernel<E> {
        SimKernel {
            clock: SimClock::new(),
            next_seq: 0,
            heap: BinaryHeap::new(),
        }
    }

    /// The kernel clock's current instant (the time of the last popped
    /// event).
    pub fn now(&self) -> SimTime {
        self.clock.now()
    }

    /// Schedule `event` at the absolute instant `at`. An instant
    /// already in the past is clamped to `now` (it will pop next, in
    /// schedule order).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        let at = at.max(self.clock.now());
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Schedule `event` `delay` seconds after `now`. Negative delays
    /// clamp to `now`.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        let at = self.clock.now() + delay.max(0.0);
        self.schedule_at(at, event);
    }

    /// The instant of the next event, without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    /// Pop the earliest event, advancing the kernel clock to its
    /// instant. `None` when the queue is empty (the simulation is
    /// over).
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        self.clock.advance_to(s.at);
        Some((s.at, s.event))
    }

    /// Pop every event whose instant is within `eps` seconds of the
    /// earliest queued event — the simultaneity batch discrete-event
    /// schedulers process under one scheduling pass. Empty when the
    /// queue is empty.
    pub fn pop_batch(&mut self, eps: f64) -> Vec<(SimTime, E)> {
        let Some(first) = self.peek_time() else {
            return Vec::new();
        };
        let cutoff = first + eps;
        let mut batch = Vec::new();
        while self.peek_time().is_some_and(|t| t <= cutoff) {
            let Some(next) = self.pop() else { break };
            batch.push(next);
        }
        batch
    }

    /// Events still queued.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_orders_totally_and_does_arithmetic() {
        let a = SimTime::from_secs(1.5);
        let b = SimTime::from_secs(2.5);
        assert!(a < b);
        assert_eq!(b - a, 1.0);
        assert_eq!(a + 1.0, b);
        assert_eq!(b - 1.0, a);
        let mut c = a;
        c += 1.0;
        assert_eq!(c, b);
        assert_eq!(SimTime::ZERO.as_secs_f64(), 0.0);
        assert_eq!(SimTime::default(), SimTime::ZERO);
        assert_eq!(a.max(b), b);
        assert_eq!(b.max(a), b);
        assert_eq!(format!("{a}"), "1.5s");
        // total order handles signed zero
        assert!(SimTime::from_secs(-0.0) <= SimTime::from_secs(0.0));
    }

    #[test]
    fn clock_is_monotonic() {
        let mut clock = SimClock::new();
        assert_eq!(clock.now(), SimTime::ZERO);
        clock.advance(2.0);
        assert_eq!(clock.now(), SimTime::from_secs(2.0));
        clock.advance_to(SimTime::from_secs(1.0)); // backward: no-op
        assert_eq!(clock.now(), SimTime::from_secs(2.0));
        clock.advance_to(SimTime::from_secs(3.0));
        assert_eq!(clock.now(), SimTime::from_secs(3.0));
    }

    #[test]
    fn events_pop_in_time_then_seq_order() {
        let mut k: SimKernel<u32> = SimKernel::new();
        k.schedule_at(SimTime::from_secs(5.0), 50);
        k.schedule_at(SimTime::from_secs(1.0), 10);
        k.schedule_at(SimTime::from_secs(5.0), 51); // ties pop FIFO
        k.schedule_at(SimTime::from_secs(3.0), 30);
        assert_eq!(k.len(), 4);
        let popped: Vec<(f64, u32)> = std::iter::from_fn(|| k.pop())
            .map(|(t, e)| (t.as_secs_f64(), e))
            .collect();
        assert_eq!(
            popped,
            vec![(1.0, 10), (3.0, 30), (5.0, 50), (5.0, 51)]
        );
        assert!(k.is_empty());
        assert_eq!(k.now(), SimTime::from_secs(5.0));
    }

    #[test]
    fn relative_scheduling_follows_the_clock() {
        let mut k: SimKernel<&str> = SimKernel::new();
        k.schedule_at(SimTime::from_secs(4.0), "outer");
        let (t, _) = k.pop().unwrap();
        assert_eq!(t, SimTime::from_secs(4.0));
        k.schedule_in(2.0, "inner"); // 4.0 + 2.0
        k.schedule_in(-1.0, "clamped"); // negative delay clamps to now
        let (t1, e1) = k.pop().unwrap();
        assert_eq!((t1, e1), (SimTime::from_secs(4.0), "clamped"));
        let (t2, e2) = k.pop().unwrap();
        assert_eq!((t2, e2), (SimTime::from_secs(6.0), "inner"));
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut k: SimKernel<u8> = SimKernel::new();
        k.schedule_at(SimTime::from_secs(10.0), 1);
        k.pop().unwrap();
        k.schedule_at(SimTime::from_secs(3.0), 2); // in the past
        let (t, e) = k.pop().unwrap();
        assert_eq!((t, e), (SimTime::from_secs(10.0), 2));
        assert_eq!(k.now(), SimTime::from_secs(10.0));
    }

    #[test]
    fn pop_batch_takes_the_simultaneity_window() {
        let mut k: SimKernel<u32> = SimKernel::new();
        k.schedule_at(SimTime::from_secs(1.0), 1);
        k.schedule_at(SimTime::from_secs(1.0 + 1e-12), 2);
        k.schedule_at(SimTime::from_secs(2.0), 3);
        let batch = k.pop_batch(1e-9);
        let ids: Vec<u32> = batch.into_iter().map(|(_, e)| e).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(k.len(), 1);
        let rest = k.pop_batch(1e-9);
        assert_eq!(rest.len(), 1);
        assert!(k.pop_batch(1e-9).is_empty());
    }

    #[test]
    fn million_event_heap_is_fast_and_ordered() {
        // the sim_scale workload shape in miniature: interleaved
        // schedule/pop with adversarial insertion order
        let mut k: SimKernel<usize> = SimKernel::new();
        let n = 100_000usize;
        for i in 0..n {
            // reversed times: worst case for a naive sorted-vec queue
            let t = ((n - i) as f64) * 1e-3;
            k.schedule_at(SimTime::from_secs(t), i);
        }
        let mut last = SimTime::ZERO;
        let mut popped = 0usize;
        while let Some((t, _)) = k.pop() {
            assert!(t >= last);
            last = t;
            popped += 1;
        }
        assert_eq!(popped, n);
    }
}
