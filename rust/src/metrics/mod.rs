//! Measurement protocol and statistics.
//!
//! The paper reports "best results after 30 repetitions" for the OSU,
//! n-body and PyFR experiments, and mean ± stddev over 30 runs for
//! Pynamic (Fig. 3). This module implements both protocols plus the table
//! renderer the bench harnesses use to print paper-shaped rows.

pub const PAPER_REPETITIONS: usize = 30;

/// Summary statistics over a set of repetitions.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub best: f64,
    pub worst: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile — tail latency for the distribution benches.
    pub p95: f64,
    /// 99th percentile — the SLO metric `gateway_scale` reports.
    pub p99: f64,
}

/// Nearest-rank percentile of an ascending-sorted sample set, `q` in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "no samples");
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Stats {
            n,
            mean,
            std: var.sqrt(),
            best: sorted[0],
            worst: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

impl Stats {
    /// JSON object with the summary fields the `BENCH_*.json` artifacts
    /// share (`n`, `mean`, `std`, `best`, `worst`, `p50`, `p95`, `p99`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean)),
            ("std", Json::Num(self.std)),
            ("best", Json::Num(self.best)),
            ("worst", Json::Num(self.worst)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
        ])
    }
}

/// Run `f` for the paper's 30 repetitions and summarize.
pub fn repeat<F: FnMut(usize) -> f64>(mut f: F) -> Stats {
    repeat_n(PAPER_REPETITIONS, &mut f)
}

pub fn repeat_n<F: FnMut(usize) -> f64>(n: usize, f: &mut F) -> Stats {
    let samples: Vec<f64> = (0..n).map(|rep| f(rep)).collect();
    Stats::from_samples(&samples)
}

/// Plain-text table renderer for the bench harnesses (prints the same
/// rows/columns the paper's tables report).
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.worst, 3.0);
    }

    #[test]
    fn stats_single_sample_has_zero_std() {
        let s = Stats::from_samples(&[5.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.best, 5.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn stats_serialize_to_json() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        let j = s.to_json();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("worst").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("p50").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn percentiles_nearest_rank() {
        // 1..=100: p50 = 50, p95 = 95, p99 = 99 under nearest-rank
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Stats::from_samples(&samples);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.worst, 100.0);
        // order of the input must not matter
        let mut rev = samples.clone();
        rev.reverse();
        assert_eq!(Stats::from_samples(&rev), s);
    }

    #[test]
    fn percentile_sorted_small_sets() {
        assert_eq!(percentile_sorted(&[3.0], 0.99), 3.0);
        assert_eq!(percentile_sorted(&[1.0, 2.0], 0.5), 1.0);
        assert_eq!(percentile_sorted(&[1.0, 2.0], 0.51), 2.0);
        assert_eq!(percentile_sorted(&[1.0, 2.0, 3.0], 1.0), 3.0);
        // q=0 clamps to the first sample instead of underflowing
        assert_eq!(percentile_sorted(&[1.0, 2.0, 3.0], 0.0), 1.0);
    }

    #[test]
    fn repeat_runs_thirty() {
        let mut count = 0;
        let s = repeat(|rep| {
            count += 1;
            rep as f64
        });
        assert_eq!(count, PAPER_REPETITIONS);
        assert_eq!(s.n, PAPER_REPETITIONS);
        assert_eq!(s.best, 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["Size", "Native"]);
        t.row(&["32".into(), "1.2".into()]);
        t.row(&["2M".into(), "480.8".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("Size"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["x".into()]);
    }

    // -- property tests (deterministic PRNG, no external crates) ----------

    use crate::util::prng::Rng;

    /// Fisher–Yates with the repo PRNG — permutation-invariance driver.
    fn shuffled(samples: &[f64], rng: &mut Rng) -> Vec<f64> {
        let mut v = samples.to_vec();
        for i in (1..v.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }

    #[test]
    fn prop_stats_are_permutation_invariant() {
        let mut rng = Rng::from_tags(&["metrics", "prop", "perm"]);
        for case in 0..50 {
            let n = 1 + rng.below(40) as usize;
            let samples: Vec<f64> =
                (0..n).map(|_| rng.range(-1e3, 1e3)).collect();
            let reference = Stats::from_samples(&samples);
            for _ in 0..4 {
                let permuted = shuffled(&samples, &mut rng);
                let s = Stats::from_samples(&permuted);
                // order statistics are exact under permutation; mean and
                // std only up to summation-order rounding
                for (got, want) in [
                    (s.best, reference.best),
                    (s.worst, reference.worst),
                    (s.p50, reference.p50),
                    (s.p95, reference.p95),
                    (s.p99, reference.p99),
                ] {
                    assert_eq!(
                        got, want,
                        "case {case}: order statistics must not depend \
                         on sample order"
                    );
                }
                assert_eq!(s.n, reference.n);
                assert!(
                    (s.mean - reference.mean).abs()
                        <= 1e-9 * (1.0 + reference.mean.abs()),
                    "case {case}: mean drifted past rounding"
                );
                assert!(
                    (s.std - reference.std).abs()
                        <= 1e-9 * (1.0 + reference.std),
                    "case {case}: std drifted past rounding"
                );
            }
        }
    }

    #[test]
    fn prop_percentile_is_monotone_in_q_and_bounded() {
        let mut rng = Rng::from_tags(&["metrics", "prop", "mono"]);
        for _ in 0..50 {
            let n = 1 + rng.below(60) as usize;
            let mut sorted: Vec<f64> =
                (0..n).map(|_| rng.range(-50.0, 50.0)).collect();
            sorted.sort_by(f64::total_cmp);
            let qs: Vec<f64> = (0..=20).map(|i| i as f64 / 20.0).collect();
            let mut last = f64::NEG_INFINITY;
            for &q in &qs {
                let p = percentile_sorted(&sorted, q);
                assert!(p >= last, "percentile must be monotone in q");
                assert!(p >= sorted[0] && p <= sorted[n - 1]);
                last = p;
            }
            // the boundaries are the extremes, never an out-of-range rank
            assert_eq!(percentile_sorted(&sorted, 0.0), sorted[0]);
            assert_eq!(percentile_sorted(&sorted, 1.0), sorted[n - 1]);
        }
    }

    #[test]
    fn prop_single_sample_is_every_statistic() {
        let mut rng = Rng::from_tags(&["metrics", "prop", "single"]);
        for _ in 0..20 {
            let x = rng.range(-1e6, 1e6);
            let s = Stats::from_samples(&[x]);
            assert_eq!(s.n, 1);
            assert_eq!(s.std, 0.0);
            for v in [s.mean, s.best, s.worst, s.p50, s.p95, s.p99] {
                assert_eq!(v, x);
            }
        }
    }

    #[test]
    fn prop_order_statistics_agree_with_sorted_ranks() {
        // best/worst/p50 must be exact order statistics of the input
        let mut rng = Rng::from_tags(&["metrics", "prop", "ranks"]);
        for _ in 0..50 {
            let n = 1 + rng.below(30) as usize;
            let samples: Vec<f64> =
                (0..n).map(|_| rng.range(0.0, 10.0)).collect();
            let mut sorted = samples.clone();
            sorted.sort_by(f64::total_cmp);
            let s = Stats::from_samples(&samples);
            assert_eq!(s.best, sorted[0]);
            assert_eq!(s.worst, sorted[n - 1]);
            let rank = (0.5 * n as f64).ceil() as usize;
            assert_eq!(s.p50, sorted[rank.clamp(1, n) - 1]);
        }
    }

    #[test]
    fn total_cmp_orders_negatives_and_signed_zero() {
        // total_cmp gives a NaN-free total order: -0.0 sorts before 0.0
        // and negatives sort below, so percentiles stay well-defined
        let s = Stats::from_samples(&[0.0, -1.5, -0.0, 2.5, -3.25]);
        assert_eq!(s.best, -3.25);
        assert_eq!(s.worst, 2.5);
        assert_eq!(s.p50, -0.0);
        assert!(s.p50.is_sign_negative(), "-0.0 ranks below +0.0");
    }
}
