//! Measurement protocol and statistics.
//!
//! The paper reports "best results after 30 repetitions" for the OSU,
//! n-body and PyFR experiments, and mean ± stddev over 30 runs for
//! Pynamic (Fig. 3). This module implements both protocols plus the table
//! renderer the bench harnesses use to print paper-shaped rows.

pub const PAPER_REPETITIONS: usize = 30;

/// Summary statistics over a set of repetitions.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub best: f64,
    pub worst: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let best = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let worst = samples.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        Stats {
            n,
            mean,
            std: var.sqrt(),
            best,
            worst,
        }
    }
}

/// Run `f` for the paper's 30 repetitions and summarize.
pub fn repeat<F: FnMut(usize) -> f64>(mut f: F) -> Stats {
    repeat_n(PAPER_REPETITIONS, &mut f)
}

pub fn repeat_n<F: FnMut(usize) -> f64>(n: usize, f: &mut F) -> Stats {
    let samples: Vec<f64> = (0..n).map(|rep| f(rep)).collect();
    Stats::from_samples(&samples)
}

/// Plain-text table renderer for the bench harnesses (prints the same
/// rows/columns the paper's tables report).
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.worst, 3.0);
    }

    #[test]
    fn stats_single_sample_has_zero_std() {
        let s = Stats::from_samples(&[5.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.best, 5.0);
    }

    #[test]
    fn repeat_runs_thirty() {
        let mut count = 0;
        let s = repeat(|rep| {
            count += 1;
            rep as f64
        });
        assert_eq!(count, PAPER_REPETITIONS);
        assert_eq!(s.n, PAPER_REPETITIONS);
        assert_eq!(s.best, 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["Size", "Native"]);
        t.row(&["32".into(), "1.2".into()]);
        t.row(&["2M".into(), "480.8".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("Size"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["x".into()]);
    }
}
