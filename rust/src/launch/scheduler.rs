//! The launch scheduler (DESIGN.md S19): WLM allocation → one coalesced
//! pull → per-node stage execution on the virtual-time kernel →
//! aggregation.
//!
//! Execution model (DESIGN.md S24): node slots are events on a
//! [`crate::sim::SimKernel`], not tasks on a thread pool. Every slot's
//! start is scheduled at the caller's trace instant; popping a start
//! event runs the slot's attempt sequence and schedules its completion
//! at `start + total_secs` in virtual time. Events pop in deterministic
//! `(time, seq)` order, so reports and telemetry are bit-identical
//! across runs and host thread counts — there is no interleaving to be
//! robust against. All jitter is PRNG-keyed on `(image, node, attempt)`.
//!
//! Straggler/retry policy: each attempt draws a lognormal jitter
//! multiplier. A multiplier above `RetryPolicy::straggler_threshold`
//! marks the slot a straggler and relaunches it — the squashfs is already
//! node-local by then, so the retry resolves against the warm cache, which
//! is exactly what a real site's "cancel the slow node and relaunch"
//! mitigation buys. Transient cold-fill faults burn their broadcast time
//! and retry; container-side errors (MPI ABI mismatch, GPU incompat,
//! missing host libraries) are permanent and fail only their own slot.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::config::UdiRootConfig;
use crate::distrib::DistributionFabric;
use crate::gateway::{ImageSource, PullState};
use crate::registry::Registry;
use crate::shifter::{
    preflight, ExtensionRegistry, RunOptions, ShifterRuntime,
};
use crate::sim::{SimKernel, SimTime};
use crate::telemetry::{SpanDraft, Telemetry, TraceCtx};
use crate::util::prng::Rng;
use crate::util::sync::lock_unpoisoned;
use crate::wlm::{GresRequest, Slurm, WlmError};

use super::report::{LaunchReport, NodeResult, PullSummary};
use super::{JobSpec, LaunchCluster};

/// Events the per-job launch kernel schedules (DESIGN.md S24): one
/// start/done pair per node slot.
enum SlotEvent {
    /// Begin slot `i`'s attempt sequence at the scheduled instant.
    Start(usize),
    /// Slot `i` reached its terminal state (success or per-slot error).
    Done(usize),
}

/// Identity of a slot class for the template fast path: partition,
/// image, and the launch-environment fingerprint.
type TemplateKey = (usize, String, Vec<(String, Option<String>)>);

/// Cached outcome of the first full stage-pipeline run of a slot class:
/// everything but the squashfs fetch is identical across the class, so
/// replays recompute only the fetch term.
struct SlotTemplate {
    /// Startup overhead of the seeding run (its fetch included).
    overhead_secs: f64,
    /// Fetch component the seeding run was charged.
    fetch_secs: f64,
    /// Index of the prepare-environment entry in `stage_secs` (the one
    /// stage whose cost embeds the fetch).
    prepare_idx: usize,
    stage_secs: Vec<(&'static str, f64)>,
    gpu_libraries: Vec<String>,
    host_mpi: Option<String>,
    extensions: Vec<&'static str>,
}

/// What one attempt produced, template-replayed or fully run.
struct AttemptRun {
    overhead_secs: f64,
    stage_secs: Vec<(&'static str, f64)>,
    gpu_libraries: Vec<String>,
    host_mpi: Option<String>,
    extensions: Vec<&'static str>,
}

/// Env fingerprint for the slot-template cache: rank-varying WLM ids
/// contribute their key only — their values never change stage costs
/// (export cost is per-variable, not per-byte) and the stock extension
/// triggers ignore them — while every other variable contributes key
/// and value, so anything trigger-relevant (`CUDA_VISIBLE_DEVICES`,
/// `SHIFTER_NET`, `--mpi` labels) keys its own template.
fn env_fingerprint(
    env: &BTreeMap<String, String>,
) -> Vec<(String, Option<String>)> {
    const RANK_VARYING: [&str; 4] =
        ["ALPS_APP_PE", "PMI_RANK", "SLURM_LOCALID", "SLURM_PROCID"];
    env.iter()
        .map(|(k, v)| {
            if RANK_VARYING.contains(&k.as_str()) {
                (k.clone(), None)
            } else {
                (k.clone(), Some(v.clone()))
            }
        })
        .collect()
}

/// Whole-job failures: anything that kills the launch before (or while)
/// slots can be planned. Per-slot failures land in
/// [`super::report::NodeResult::error`] instead.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum LaunchError {
    /// The WLM rejected the job outright (e.g. more nodes than exist).
    #[error(transparent)]
    Wlm(#[from] WlmError),
    /// The coalesced gateway pull did not reach READY.
    #[error("image pull failed for {reference}: {detail}")]
    Pull {
        /// Image reference that failed to pull.
        reference: String,
        /// Terminal gateway error, verbatim.
        detail: String,
    },
    /// The job requested zero nodes.
    #[error("job requests zero nodes")]
    EmptyJob,
    /// An explicit node set handed to [`LaunchScheduler::launch_on`] is
    /// inconsistent (wrong length, duplicate or unknown node ids).
    #[error("invalid node set: {0}")]
    BadNodeSet(String),
}

/// Straggler and transient-failure handling knobs.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Attempts per node slot (>= 1).
    pub max_attempts: u32,
    /// Lognormal sigma of per-attempt node jitter.
    pub jitter_sigma: f64,
    /// An attempt whose jitter multiplier exceeds this is a straggler and
    /// is relaunched while attempts remain.
    pub straggler_threshold: f64,
    /// Probability that a slot's first cold-cache fill fails outright
    /// (transient Lustre read error); the retry re-reads cleanly.
    pub cold_fill_fault_rate: f64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 3,
            jitter_sigma: 0.05,
            straggler_threshold: 1.12,
            cold_fill_fault_rate: 0.0,
        }
    }
}

impl RetryPolicy {
    /// No jitter, no faults, single attempt — for exact-count tests.
    pub fn strict() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            jitter_sigma: 0.0,
            straggler_threshold: f64::INFINITY,
            cold_fill_fault_rate: 0.0,
        }
    }
}

/// Per-slot plan produced by the WLM phase.
struct SlotPlan {
    node: u32,
    partition: usize,
    env: BTreeMap<String, String>,
    /// Set when WLM allocation or preflight already failed the slot.
    dead: Option<String>,
}

/// Drives one [`JobSpec`] across a [`LaunchCluster`] end to end: WLM
/// allocation, one coalesced fabric pull, per-node stage execution on a
/// worker pool, aggregation into a [`LaunchReport`].
///
/// The scheduler is re-entrant: it holds no per-launch state, so one
/// instance can run any number of jobs back to back against a shared
/// [`DistributionFabric`] — the multi-tenant layer (`crate::tenancy`)
/// does exactly that, placing each job on an explicit node set via
/// [`LaunchScheduler::launch_on`].
pub struct LaunchScheduler<'a> {
    cluster: &'a LaunchCluster,
    registry: &'a Registry,
    policy: RetryPolicy,
    config: Option<UdiRootConfig>,
    extensions: Option<Arc<ExtensionRegistry>>,
    telemetry: Option<Arc<Telemetry>>,
    /// Slot-template cache for the fast path (lives for the scheduler's
    /// lifetime: a storm builds one scheduler, so templates amortize
    /// across every job it launches). Ordered so any future iteration
    /// over the cache is deterministic (S26 `unordered-collection`).
    templates: Mutex<BTreeMap<TemplateKey, SlotTemplate>>,
}

impl<'a> LaunchScheduler<'a> {
    /// Scheduler over `cluster`, resolving images against `registry`,
    /// with the default retry policy.
    pub fn new(
        cluster: &'a LaunchCluster,
        registry: &'a Registry,
    ) -> LaunchScheduler<'a> {
        LaunchScheduler {
            cluster,
            registry,
            policy: RetryPolicy::default(),
            config: None,
            extensions: None,
            telemetry: None,
            templates: Mutex::new(BTreeMap::new()),
        }
    }

    /// Replace the straggler/retry policy.
    pub fn with_policy(mut self, policy: RetryPolicy) -> LaunchScheduler<'a> {
        assert!(policy.max_attempts >= 1, "at least one attempt per slot");
        self.policy = policy;
        self
    }

    /// Retained for API compatibility; a no-op since slot execution
    /// moved onto the deterministic virtual-time kernel (DESIGN.md S24)
    /// — there is no worker pool to size, and results are identical at
    /// any width.
    pub fn with_workers(self, _workers: usize) -> LaunchScheduler<'a> {
        self
    }

    /// Run every per-partition runtime with this site `udiRoot.conf`
    /// instead of the stock per-profile config — the knob
    /// [`crate::SiteBuilder::config`] plumbs down to node execution.
    pub fn with_config(
        mut self,
        config: UdiRootConfig,
    ) -> LaunchScheduler<'a> {
        self.config = Some(config);
        self
    }

    /// Drive every per-partition runtime with this host-extension
    /// registry instead of the stock GPU/MPI/network set — the knob
    /// [`crate::SiteBuilder::with_extension`] plumbs down to node
    /// execution.
    pub fn with_extensions(
        mut self,
        extensions: Arc<ExtensionRegistry>,
    ) -> LaunchScheduler<'a> {
        self.extensions = Some(extensions);
        self
    }

    /// Share a telemetry recorder (see DESIGN.md S23): launches emit a
    /// `job` root span, a `pull` child, one `node` span per slot (with
    /// per-attempt `run`/`stage`/`ext` children from the runtime, which
    /// inherits this recorder), and the `launch.*` counters.
    pub fn with_telemetry(
        mut self,
        telemetry: Arc<Telemetry>,
    ) -> LaunchScheduler<'a> {
        self.telemetry = Some(telemetry);
        self
    }

    /// Drive `spec` across the cluster end to end, filling slots from the
    /// lowest global node id upward (the classic single-job path).
    pub fn launch(
        &self,
        fabric: &mut DistributionFabric,
        spec: &JobSpec,
    ) -> Result<LaunchReport, LaunchError> {
        if spec.nodes == 0 {
            return Err(LaunchError::EmptyJob);
        }
        if spec.nodes > self.cluster.total_nodes() {
            return Err(LaunchError::Wlm(WlmError::NotEnoughNodes {
                requested: spec.nodes,
                available: self.cluster.total_nodes(),
            }));
        }
        let slots = self.plan_slots(spec);
        self.run_planned(fabric, spec, slots, None)
    }

    /// Drive `spec` on an explicit set of global node ids — the
    /// re-entrant path a multi-job scheduler uses to place concurrent
    /// jobs on disjoint node sets over one shared fabric. The node list
    /// must match `spec.nodes` in length and name each node exactly once;
    /// nodes may span partitions (each partition's share is allocated
    /// through its own WLM instance, exactly like [`Self::launch`]).
    pub fn launch_on(
        &self,
        fabric: &mut DistributionFabric,
        spec: &JobSpec,
        nodes: &[u32],
    ) -> Result<LaunchReport, LaunchError> {
        if spec.nodes == 0 || nodes.is_empty() {
            return Err(LaunchError::EmptyJob);
        }
        if nodes.len() != spec.nodes as usize {
            return Err(LaunchError::BadNodeSet(format!(
                "spec requests {} nodes but {} were supplied",
                spec.nodes,
                nodes.len()
            )));
        }
        let slots = self.plan_slots_on(spec, nodes)?;
        self.run_planned(fabric, spec, slots, None)
    }

    /// [`Self::launch_on`] with an explicit trace placement: node spans
    /// parent under `ctx.parent` and start at the virtual-time instant
    /// `ctx.start`, instead of a fresh `job` root at t=0. This is
    /// how the multi-tenant scheduler (`crate::tenancy`) stitches each
    /// job's node execution into its own arrival→completion span.
    pub fn launch_on_traced(
        &self,
        fabric: &mut DistributionFabric,
        spec: &JobSpec,
        nodes: &[u32],
        ctx: TraceCtx,
    ) -> Result<LaunchReport, LaunchError> {
        if spec.nodes == 0 || nodes.is_empty() {
            return Err(LaunchError::EmptyJob);
        }
        if nodes.len() != spec.nodes as usize {
            return Err(LaunchError::BadNodeSet(format!(
                "spec requests {} nodes but {} were supplied",
                spec.nodes,
                nodes.len()
            )));
        }
        let slots = self.plan_slots_on(spec, nodes)?;
        self.run_planned(fabric, spec, slots, Some(ctx))
    }

    /// Shared back half of [`Self::launch`] / [`Self::launch_on`] /
    /// [`Self::launch_on_traced`]: one coalesced pull, then per-node
    /// stage execution and aggregation. `ctx` is the caller-provided
    /// trace placement; `None` means a standalone launch, which (when
    /// tracing) gets its own `job` root span at t=0 with the pull as its
    /// first child and node spans offset by the pull turnaround.
    fn run_planned(
        &self,
        fabric: &mut DistributionFabric,
        spec: &JobSpec,
        slots: Vec<SlotPlan>,
        ctx: Option<TraceCtx>,
    ) -> Result<LaunchReport, LaunchError> {
        // -- one coalesced pull for the whole job -------------------------
        let pull = self.pull_once(fabric, spec, &slots)?;

        // trace placement for node spans: a traced caller dictates parent
        // and start; a standalone launch roots its own tree at t=0 and
        // places node execution after the coalesced pull completes
        let tel = self.telemetry.as_ref().filter(|t| t.enabled());
        let (root, node_ctx) = match (tel, ctx) {
            (Some(t), None) => {
                let root = t.reserve_id();
                let turnaround = pull
                    .as_ref()
                    .map_or(0.0, |p: &PullSummary| p.turnaround_secs);
                t.span(SpanDraft {
                    parent: root,
                    category: "pull",
                    name: &format!("pull:{}", spec.image),
                    track: "gateway",
                    start: SimTime::ZERO,
                    dur_secs: turnaround,
                });
                (
                    root,
                    TraceCtx {
                        parent: root,
                        start: SimTime::from_secs(turnaround),
                    },
                )
            }
            (_, Some(c)) => (None, c),
            (None, None) => (None, TraceCtx::default()),
        };

        // -- per-node stage execution on the worker pool ------------------
        let runtimes: Vec<ShifterRuntime> = self
            .cluster
            .partitions()
            .iter()
            .map(|p| {
                let rt = match &self.extensions {
                    Some(ext) => p.runtime_with_extensions(
                        self.config.as_ref(),
                        Arc::clone(ext),
                    ),
                    None => p.runtime(self.config.as_ref()),
                };
                match &self.telemetry {
                    Some(t) => rt.with_telemetry(Arc::clone(t)),
                    None => rt,
                }
            })
            .collect();
        let fabric_ref: &DistributionFabric = fabric;
        let mut kernel: SimKernel<SlotEvent> = SimKernel::new();
        for i in 0..slots.len() {
            kernel.schedule_at(node_ctx.start, SlotEvent::Start(i));
        }
        let mut results: Vec<Option<NodeResult>> =
            slots.iter().map(|_| None).collect();
        while let Some((_, event)) = kernel.pop() {
            match event {
                SlotEvent::Start(i) => {
                    let r = self.run_slot(
                        &runtimes, fabric_ref, spec, &slots[i], node_ctx,
                    );
                    // the completion lands on the shared clock, so the
                    // kernel's final instant is the job makespan
                    kernel.schedule_in(
                        r.total_secs.max(0.0),
                        SlotEvent::Done(i),
                    );
                    results[i] = Some(r);
                }
                SlotEvent::Done(i) => {
                    debug_assert!(
                        results[i].is_some(),
                        "completion event before its slot ran"
                    );
                }
            }
        }
        // Every Start event filled its slot before its Done event popped;
        // flatten keeps that invariant checkable without a panic site.
        let node_results: Vec<NodeResult> = results.into_iter().flatten().collect();
        debug_assert_eq!(
            node_results.len(),
            slots.len(),
            "every slot produces a result"
        );

        // close the standalone root around whatever its children (pull +
        // node spans) actually covered
        if let (Some(t), Some(root_id)) = (tel, root) {
            let end = t
                .child_span_end(root_id)
                .unwrap_or(node_ctx.start_secs());
            t.span_as(
                root_id,
                SpanDraft {
                    parent: None,
                    category: "job",
                    name: &format!("job:{}", spec.image),
                    track: "jobs",
                    start: SimTime::ZERO,
                    dur_secs: end,
                },
            );
        }

        let cas = fabric.cluster().cas();
        Ok(LaunchReport {
            image: spec.image.clone(),
            nodes_requested: spec.nodes,
            node_results,
            pull,
            cache: fabric.cache_stats(),
            cas_dedup_ratio: cas.dedup_ratio(),
        })
    }

    /// WLM phase: walk partitions in node order, salloc + srun each one's
    /// share. A partition whose allocation or preflight fails marks only
    /// its own slots dead — it cannot poison the rest of the job.
    fn plan_slots(&self, spec: &JobSpec) -> Vec<SlotPlan> {
        let gres = (spec.gpus_per_node > 0).then_some(GresRequest {
            gpus_per_node: spec.gpus_per_node,
        });
        let mut slots: Vec<SlotPlan> = Vec::with_capacity(spec.nodes as usize);
        let mut remaining = spec.nodes;
        for (pidx, part) in self.cluster.partitions().iter().enumerate() {
            if remaining == 0 {
                break;
            }
            let take = remaining.min(part.node_count());
            remaining -= take;
            let chosen: Vec<u32> =
                (part.first_node()..part.first_node() + take).collect();
            self.plan_partition_slots(pidx, &chosen, gres, &mut slots);
        }
        slots
    }

    /// WLM phase for an explicit node set: validate it, group by
    /// partition, then allocate each partition's share.
    fn plan_slots_on(
        &self,
        spec: &JobSpec,
        nodes: &[u32],
    ) -> Result<Vec<SlotPlan>, LaunchError> {
        let gres = (spec.gpus_per_node > 0).then_some(GresRequest {
            gpus_per_node: spec.gpus_per_node,
        });
        let mut seen = std::collections::BTreeSet::new();
        let mut per_part: Vec<Vec<u32>> =
            vec![Vec::new(); self.cluster.partitions().len()];
        for &node in nodes {
            let pidx = self
                .cluster
                .partitions()
                .iter()
                .position(|p| p.contains(node))
                .ok_or_else(|| {
                    LaunchError::BadNodeSet(format!(
                        "node {node} is outside every partition"
                    ))
                })?;
            if !seen.insert(node) {
                return Err(LaunchError::BadNodeSet(format!(
                    "node {node} listed twice"
                )));
            }
            per_part[pidx].push(node);
        }
        let mut slots: Vec<SlotPlan> = Vec::with_capacity(nodes.len());
        for (pidx, chosen) in per_part.iter().enumerate() {
            if !chosen.is_empty() {
                self.plan_partition_slots(pidx, chosen, gres, &mut slots);
            }
        }
        Ok(slots)
    }

    /// Allocate `chosen` (nodes of one partition) via that partition's
    /// WLM: preflight, salloc, srun-with-GRES. Failures mark only these
    /// slots dead.
    fn plan_partition_slots(
        &self,
        pidx: usize,
        chosen: &[u32],
        gres: Option<GresRequest>,
        slots: &mut Vec<SlotPlan>,
    ) {
        let part = &self.cluster.partitions()[pidx];
        let take = chosen.len() as u32;
        let dead_all = |reason: String, slots: &mut Vec<SlotPlan>| {
            for &node in chosen {
                slots.push(SlotPlan {
                    node,
                    partition: pidx,
                    env: BTreeMap::new(),
                    dead: Some(reason.clone()),
                });
            }
        };
        let pre = preflight::preflight(part.profile());
        if !pre.ok() {
            dead_all(
                format!(
                    "preflight: kernel {} lacks {:?}",
                    part.profile().kernel,
                    pre.missing
                ),
                slots,
            );
            return;
        }
        let mut slurm = Slurm::new(part.profile());
        let ranks = slurm
            .salloc(take)
            .and_then(|alloc| slurm.srun(&alloc, take, gres));
        match ranks {
            Ok(ranks) => {
                for rank in ranks {
                    slots.push(SlotPlan {
                        // one task per node: rank.node indexes `chosen`
                        node: chosen[rank.node as usize],
                        partition: pidx,
                        env: rank.env,
                        dead: None,
                    });
                }
            }
            Err(e) => dead_all(format!("wlm: {e}"), slots),
        }
    }

    /// Pull phase: every live slot requests the image; the shard queue's
    /// dedup coalesces the storm into exactly one job, and an exact
    /// event-time drain runs it to a terminal state.
    fn pull_once(
        &self,
        fabric: &mut DistributionFabric,
        spec: &JobSpec,
        slots: &[SlotPlan],
    ) -> Result<Option<PullSummary>, LaunchError> {
        let live = slots.iter().filter(|s| s.dead.is_none()).count();
        if live == 0 {
            return Ok(None);
        }
        for slot in slots.iter().filter(|s| s.dead.is_none()) {
            fabric
                .request(
                    self.registry,
                    &spec.image,
                    &format!("node-{:05}", slot.node),
                )
                .map_err(|e| LaunchError::Pull {
                    reference: spec.image.clone(),
                    detail: e.to_string(),
                })?;
        }
        fabric.drain(self.registry);
        let job = fabric.cluster().status(&spec.image);
        match job {
            Some(j) if j.state == PullState::Ready => Ok(Some(PullSummary {
                queue_wait_secs: j.queue_wait_secs().unwrap_or(0.0),
                turnaround_secs: j.turnaround_secs().unwrap_or(0.0),
                requesters: j.requesters.len(),
                jobs_total: fabric
                    .cluster()
                    .shards()
                    .map(|s| s.queue.jobs().count())
                    .sum(),
            })),
            other => Err(LaunchError::Pull {
                reference: spec.image.clone(),
                detail: other
                    .and_then(|j| j.error.clone())
                    .unwrap_or_else(|| "pull did not reach READY".to_string()),
            }),
        }
    }

    /// Execute one node slot, retrying per policy. `node_ctx` is the
    /// trace placement node spans land under (ignored unless a recorder
    /// is installed and enabled). The attempt cursor advances by at
    /// least each attempt's stage-sum so the runtime's per-attempt spans
    /// stay contained even when the charged (jittered) time is shorter.
    fn run_slot(
        &self,
        runtimes: &[ShifterRuntime],
        fabric: &DistributionFabric,
        spec: &JobSpec,
        slot: &SlotPlan,
        node_ctx: TraceCtx,
    ) -> NodeResult {
        let tel = self.telemetry.as_ref().filter(|t| t.enabled());
        let node_span = tel.and_then(|t| t.reserve_id());
        let track = format!("node-{:05}", slot.node);
        let base = node_ctx.start_secs();
        let mut cursor = base;
        let part = &self.cluster.partitions()[slot.partition];
        let mut result = NodeResult {
            node: slot.node,
            partition: part.name().to_string(),
            attempts: 0,
            straggler: false,
            total_secs: 0.0,
            stage_secs: Vec::new(),
            gpu_libraries: Vec::new(),
            host_mpi: None,
            extensions: Vec::new(),
            error: None,
        };
        if let Some(reason) = &slot.dead {
            result.error = Some(reason.clone());
            if let Some(t) = tel {
                t.count("launch.slots", 1);
                t.count("launch.failed_slots", 1);
            }
            return result;
        }
        let rt = &runtimes[slot.partition];
        let command: Vec<&str> =
            spec.command.iter().map(|s| s.as_str()).collect();
        let mut opts = RunOptions::new(&spec.image, &command)
            .on_nodes(slot.node as usize, spec.nodes);
        opts.invoking_uid = spec.invoking_uid;
        opts.invoking_gid = spec.invoking_gid;
        opts.mpi = spec.mpi;
        // job-level env first, then the WLM's per-rank variables — the
        // WLM wins on conflicts (it owns CUDA_VISIBLE_DEVICES)
        opts.env = spec.env.clone();
        opts.env.extend(slot.env.clone());
        opts.trace_parent = node_span;

        // slot-template fast path (DESIGN.md S24): with telemetry off,
        // the stock extension set and no user volumes, every slot of one
        // (partition, image, env-class) runs identical stage costs
        // except the squashfs fetch, so the first slot's full run seeds
        // a template the rest replay — recomputing (and charging the
        // node cache for) only the fetch.
        let template_key = (tel.is_none()
            && rt.extensions().is_stock()
            && opts.volumes.is_empty())
        .then(|| {
            (
                slot.partition,
                spec.image.clone(),
                env_fingerprint(&opts.env),
            )
        });

        loop {
            result.attempts += 1;
            let mut rng = Rng::from_tags(&[
                "launch",
                &spec.image,
                &slot.node.to_string(),
                &result.attempts.to_string(),
            ]);
            if result.attempts == 1
                && rng.uniform() < self.policy.cold_fill_fault_rate
            {
                // the broadcast read ran (and failed) — its time is spent,
                // and nothing was admitted to the node cache
                let wasted = self.fill_penalty_secs(fabric, spec)
                    * rng.lognormal_noise(self.policy.jitter_sigma);
                result.total_secs += wasted;
                if let Some(t) = tel {
                    t.span(SpanDraft {
                        parent: node_span,
                        category: "fault",
                        name: "cold-fill-fault",
                        track: &track,
                        start: SimTime::from_secs(cursor),
                        dur_secs: wasted,
                    });
                    t.count("launch.cold_fill_faults", 1);
                }
                cursor += wasted;
                if result.attempts >= self.policy.max_attempts {
                    result.error = Some(
                        "transient cold-fill I/O error (attempts exhausted)"
                            .to_string(),
                    );
                    break;
                }
                continue;
            }
            opts.trace_start = SimTime::from_secs(cursor);
            match self.run_attempt(
                rt,
                fabric,
                spec,
                slot,
                &mut opts,
                template_key.as_ref(),
            ) {
                Ok(attempt) => {
                    let noise =
                        rng.lognormal_noise(self.policy.jitter_sigma);
                    let overhead = attempt.overhead_secs;
                    result.total_secs += overhead * noise;
                    cursor += (overhead * noise).max(overhead);
                    if noise > self.policy.straggler_threshold {
                        result.straggler = true;
                        if result.attempts < self.policy.max_attempts {
                            // relaunch: the squashfs is node-local now, so
                            // the retry resolves against the warm cache
                            continue;
                        }
                    }
                    result.stage_secs = attempt.stage_secs;
                    result.gpu_libraries = attempt.gpu_libraries;
                    result.host_mpi = attempt.host_mpi;
                    result.extensions = attempt.extensions;
                    break;
                }
                Err(e) => {
                    // container-side errors are permanent for this job:
                    // an ABI mismatch or GPU incompatibility will not heal
                    // on retry, and must only fail this slot
                    result.error = Some(e);
                    break;
                }
            }
        }
        if let Some(t) = tel {
            if let Some(id) = node_span {
                t.span_as(
                    id,
                    SpanDraft {
                        parent: node_ctx.parent,
                        category: "node",
                        name: &format!("node:{:05}", slot.node),
                        track: &track,
                        start: SimTime::from_secs(base),
                        dur_secs: cursor - base,
                    },
                );
                t.annotate(id, "attempts", &result.attempts.to_string());
                t.annotate(id, "partition", &result.partition);
            }
            t.count("launch.slots", 1);
            t.count(
                "launch.retries",
                u64::from(result.attempts.saturating_sub(1)),
            );
            if result.straggler {
                t.count("launch.stragglers", 1);
            }
            if result.error.is_some() {
                t.count("launch.failed_slots", 1);
            }
        }
        result
    }

    /// One attempt of one slot: replay the class template when the fast
    /// path holds and a template exists, otherwise drive the full stage
    /// pipeline (seeding the template for the rest of the class). Either
    /// way the image source is charged for exactly one node fetch per
    /// attempt, so cache hit/miss accounting is identical on both paths.
    fn run_attempt(
        &self,
        rt: &ShifterRuntime,
        fabric: &DistributionFabric,
        spec: &JobSpec,
        slot: &SlotPlan,
        opts: &mut RunOptions,
        template_key: Option<&TemplateKey>,
    ) -> Result<AttemptRun, String> {
        opts.fetch_override = None;
        let fetch = template_key.and_then(|_| {
            let gw_image = fabric.resolve(&spec.image).ok()?;
            fabric.node_fetch_secs(
                gw_image,
                slot.node as usize,
                u64::from(spec.nodes.max(1)),
            )
        });
        if let (Some(key), Some(fetch)) = (template_key, fetch) {
            let templates = lock_unpoisoned(&self.templates);
            if let Some(tpl) = templates.get(key) {
                let mut stage_secs = tpl.stage_secs.clone();
                stage_secs[tpl.prepare_idx].1 += fetch - tpl.fetch_secs;
                return Ok(AttemptRun {
                    overhead_secs: tpl.overhead_secs - tpl.fetch_secs
                        + fetch,
                    stage_secs,
                    gpu_libraries: tpl.gpu_libraries.clone(),
                    host_mpi: tpl.host_mpi.clone(),
                    extensions: tpl.extensions.clone(),
                });
            }
            drop(templates);
            // miss: this attempt's fetch is already charged — hand it to
            // the runtime so the full run still costs exactly one fetch
            opts.fetch_override = Some(fetch);
        }
        let container = rt.run(fabric, opts).map_err(|e| e.to_string())?;
        let attempt = AttemptRun {
            overhead_secs: container.startup_overhead_secs(),
            stage_secs: container
                .stage_log
                .records()
                .iter()
                .map(|r| (r.stage.name(), r.sim_secs))
                .collect(),
            gpu_libraries: container
                .gpu
                .as_ref()
                .map(|g| g.libraries.clone())
                .unwrap_or_default(),
            host_mpi: container.mpi.as_ref().map(|m| m.host_mpi.clone()),
            extensions: container
                .extensions
                .iter()
                .map(|r| r.extension)
                .collect(),
        };
        if let (Some(key), Some(fetch)) =
            (template_key, opts.fetch_override)
        {
            if let Some(prepare_idx) = attempt
                .stage_secs
                .iter()
                .position(|(name, _)| *name == "prepare-environment")
            {
                lock_unpoisoned(&self.templates).insert(
                    key.clone(),
                    SlotTemplate {
                        overhead_secs: attempt.overhead_secs,
                        fetch_secs: fetch,
                        prepare_idx,
                        stage_secs: attempt.stage_secs.clone(),
                        gpu_libraries: attempt.gpu_libraries.clone(),
                        host_mpi: attempt.host_mpi.clone(),
                        extensions: attempt.extensions.clone(),
                    },
                );
            }
        }
        Ok(attempt)
    }

    /// Time a failed fill wastes before the retry — priced by the
    /// fabric's active distribution model (linear Lustre broadcast, or
    /// the spanning-tree estimate when cascade fills are enabled).
    fn fill_penalty_secs(
        &self,
        fabric: &DistributionFabric,
        spec: &JobSpec,
    ) -> f64 {
        fabric.cold_fill_estimate_secs(&spec.image, spec.nodes as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;
    use crate::pfs::LustreFs;

    fn setup(nodes: u32) -> (LaunchCluster, Registry, DistributionFabric) {
        (
            LaunchCluster::homogeneous(&SystemProfile::piz_daint(), nodes),
            Registry::dockerhub(),
            DistributionFabric::new(4, LustreFs::piz_daint()),
        )
    }

    #[test]
    fn launch_runs_every_slot_once() {
        let (cluster, registry, mut fabric) = setup(16);
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(RetryPolicy::strict())
            .with_workers(4);
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 16);
        let report = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(report.succeeded(), 16);
        assert_eq!(report.failed(), 0);
        assert_eq!(report.retries(), 0);
        let pull = report.pull.unwrap();
        assert_eq!(pull.requesters, 16);
        assert_eq!(pull.jobs_total, 1);
        // every node cold-filled exactly once
        assert_eq!(report.cache.misses, 16);
        assert_eq!(report.cache.hits, 0);
        // results come back in global node order
        let nodes: Vec<u32> =
            report.node_results.iter().map(|r| r.node).collect();
        assert_eq!(nodes, (0..16).collect::<Vec<u32>>());
        // stage percentiles exist and are ordered
        let total = report.total_stats().unwrap();
        assert!(total.p50 > 0.0);
        assert!(total.p99 >= total.p50);
    }

    #[test]
    fn warm_relaunch_is_all_cache_hits() {
        // 512 nodes: wide enough that the cold broadcast storm dominates
        // the fixed mount/exec costs and the warm restart collapses it
        let (cluster, registry, mut fabric) = setup(512);
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(RetryPolicy::strict());
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 512);
        let cold = scheduler.launch(&mut fabric, &spec).unwrap();
        let warm = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(warm.cache.hits, 512);
        assert_eq!(warm.cache.misses, 512); // from the cold launch
        let cold_p99 = cold.total_stats().unwrap().p99;
        let warm_p99 = warm.total_stats().unwrap().p99;
        assert!(
            warm_p99 * 10.0 <= cold_p99,
            "warm p99 {warm_p99}s must collapse vs cold {cold_p99}s"
        );
        // the relaunch coalesced onto the same (already READY) job
        assert_eq!(warm.pull.unwrap().jobs_total, 1);
    }

    #[test]
    fn oversubscribed_job_is_rejected() {
        let (cluster, registry, mut fabric) = setup(4);
        let scheduler = LaunchScheduler::new(&cluster, &registry);
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 5);
        let err = scheduler.launch(&mut fabric, &spec).unwrap_err();
        assert!(matches!(
            err,
            LaunchError::Wlm(WlmError::NotEnoughNodes { .. })
        ));
        let empty = JobSpec::new("ubuntu:xenial", &["true"], 0);
        assert!(matches!(
            scheduler.launch(&mut fabric, &empty).unwrap_err(),
            LaunchError::EmptyJob
        ));
    }

    #[test]
    fn missing_image_fails_the_whole_job() {
        let (cluster, registry, mut fabric) = setup(4);
        let scheduler = LaunchScheduler::new(&cluster, &registry);
        let spec = JobSpec::new("nope:missing", &["true"], 4);
        let err = scheduler.launch(&mut fabric, &spec).unwrap_err();
        assert!(matches!(err, LaunchError::Pull { .. }));
        assert!(err.to_string().contains("not found"));
    }

    #[test]
    fn transient_cold_fill_faults_retry_and_succeed() {
        let (cluster, registry, mut fabric) = setup(8);
        let policy = RetryPolicy {
            max_attempts: 2,
            jitter_sigma: 0.0,
            straggler_threshold: f64::INFINITY,
            cold_fill_fault_rate: 1.0, // every first fill fails
        };
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(policy);
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 8);
        let report = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(report.succeeded(), 8);
        assert_eq!(report.retries(), 8, "every slot burned one retry");
        assert!(report
            .node_results
            .iter()
            .all(|r| r.attempts == 2 && r.ok()));
        // the wasted broadcast time is charged to the slot
        let any = &report.node_results[0];
        let final_attempt: f64 =
            any.stage_secs.iter().map(|(_, s)| s).sum();
        assert!(any.total_secs > final_attempt);
    }

    #[test]
    fn exhausted_fault_retries_fail_only_their_slots() {
        let (cluster, registry, mut fabric) = setup(4);
        let policy = RetryPolicy {
            max_attempts: 1,
            jitter_sigma: 0.0,
            straggler_threshold: f64::INFINITY,
            cold_fill_fault_rate: 1.0,
        };
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(policy);
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
        let report = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(report.succeeded(), 0);
        assert_eq!(report.failed(), 4);
        let summary = report.failure_summary();
        assert_eq!(summary.len(), 1);
        assert!(summary[0].0.contains("cold-fill"));
        assert_eq!(summary[0].1, 4);
    }

    #[test]
    fn launch_on_places_explicit_node_sets() {
        let (cluster, registry, mut fabric) = setup(16);
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(RetryPolicy::strict());
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
        let nodes = [3u32, 7, 8, 15];
        let report = scheduler.launch_on(&mut fabric, &spec, &nodes).unwrap();
        assert_eq!(report.succeeded(), 4);
        let got: Vec<u32> =
            report.node_results.iter().map(|r| r.node).collect();
        assert_eq!(got, nodes);
        assert_eq!(report.cache.misses, 4);
        // the same nodes relaunch warm — their caches are keyed on the
        // global ids the explicit set named
        let warm = scheduler.launch_on(&mut fabric, &spec, &nodes).unwrap();
        assert_eq!(warm.cache.hits, 4);

        // inconsistent node sets are rejected up front
        for bad in [
            &[1u32, 2, 3][..],          // wrong length
            &[1u32, 1, 2, 3][..],       // duplicate
            &[1u32, 2, 3, 99][..],      // outside every partition
        ] {
            assert!(matches!(
                scheduler.launch_on(&mut fabric, &spec, bad).unwrap_err(),
                LaunchError::BadNodeSet(_)
            ));
        }
    }

    #[test]
    fn launch_on_spans_partitions() {
        let cluster = LaunchCluster::daint_linux_split(8);
        let registry = Registry::dockerhub();
        let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(RetryPolicy::strict());
        let spec = JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 4)
            .with_gpus(1);
        let report =
            scheduler.launch_on(&mut fabric, &spec, &[2, 3, 5, 6]).unwrap();
        assert_eq!(report.succeeded(), 4);
        let parts: Vec<&str> = report
            .node_results
            .iter()
            .map(|r| r.partition.as_str())
            .collect();
        assert_eq!(
            parts,
            ["daint-xc50", "daint-xc50", "linux-cluster", "linux-cluster"]
        );
    }

    #[test]
    fn telemetry_roots_one_job_span_over_pull_and_nodes() {
        let (cluster, registry, _) = setup(4);
        let tel = Arc::new(Telemetry::new(true));
        let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint())
            .with_telemetry(Arc::clone(&tel));
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_telemetry(Arc::clone(&tel));
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
        let report = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(report.succeeded(), 4);

        let spans = tel.spans();
        let roots: Vec<_> =
            spans.iter().filter(|s| s.category == "job").collect();
        assert_eq!(roots.len(), 1);
        let root = roots[0];
        assert_eq!(root.parent, None);
        assert_eq!(root.start_secs(), 0.0);
        let pull = spans.iter().find(|s| s.category == "pull").unwrap();
        assert_eq!(pull.parent, Some(root.id));
        let nodes: Vec<_> =
            spans.iter().filter(|s| s.category == "node").collect();
        assert_eq!(nodes.len(), 4);
        for n in &nodes {
            assert_eq!(n.parent, Some(root.id));
            // node execution starts where the coalesced pull ends
            assert!((n.start_secs() - pull.end_secs()).abs() < 1e-9);
            assert!(n.end_secs() <= root.end_secs() + 1e-9);
        }
        // every non-root span's parent exists, and children stay inside
        // their parent's interval (default policy: jitter can shrink the
        // charged time, never the span envelope)
        for s in spans.iter().filter(|s| s.parent.is_some()) {
            let p = spans
                .iter()
                .find(|c| Some(c.id) == s.parent)
                .expect("parent span recorded");
            assert!(s.start_secs() >= p.start_secs() - 1e-9);
            assert!(s.end_secs() <= p.end_secs() + 1e-9);
        }
        assert_eq!(tel.counter("launch.slots"), 4);
        assert!(tel.counter("runtime.runs") >= 4);
    }

    #[test]
    fn template_fast_path_matches_the_full_pipeline() {
        use crate::netfab::NetworkSupport;
        use crate::shifter::extension::{GpuExtension, MpiExtension};
        let (cluster, registry, mut fast_fabric) = setup(32);
        let (_, _, mut slow_fabric) = setup(32);
        // same extension *behavior*, but a hand-registered set clears the
        // stock flag, forcing the full stage pipeline on every slot
        let hand_built = Arc::new(
            ExtensionRegistry::empty()
                .with(Box::new(GpuExtension))
                .with(Box::new(MpiExtension))
                .with(Box::new(NetworkSupport)),
        );
        let fast = LaunchScheduler::new(&cluster, &registry);
        let slow = LaunchScheduler::new(&cluster, &registry)
            .with_extensions(hand_built);
        // default policy: jitter + straggler retries exercise the warm
        // template-replay attempts too
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 32);
        let cold = [
            fast.launch(&mut fast_fabric, &spec).unwrap(),
            slow.launch(&mut slow_fabric, &spec).unwrap(),
        ];
        let warm = [
            fast.launch(&mut fast_fabric, &spec).unwrap(),
            slow.launch(&mut slow_fabric, &spec).unwrap(),
        ];
        for [a, b] in [cold, warm] {
            assert_eq!(a.succeeded(), b.succeeded());
            assert_eq!(a.retries(), b.retries());
            assert_eq!(a.stragglers(), b.stragglers());
            assert_eq!(a.cache.hits, b.cache.hits);
            assert_eq!(a.cache.misses, b.cache.misses);
            for (x, y) in a.node_results.iter().zip(&b.node_results) {
                assert_eq!(x.node, y.node);
                assert_eq!(x.attempts, y.attempts);
                assert_eq!(x.extensions, y.extensions);
                // replay recombines the fetch term, so allow float
                // round-off — the paths must agree to an ulp, not a bit
                let rel = (x.total_secs - y.total_secs).abs()
                    / y.total_secs.max(1e-12);
                assert!(
                    rel < 1e-9,
                    "node {}: fast {} vs full {}",
                    x.node,
                    x.total_secs,
                    y.total_secs
                );
            }
        }
    }

    #[test]
    fn stragglers_are_detected_and_relaunched() {
        let (cluster, registry, mut fabric) = setup(64);
        // sigma 0.05 with threshold 1.0: every positive-jitter attempt
        // (about half) straggles — plenty of retries, all terminating
        let policy = RetryPolicy {
            max_attempts: 2,
            jitter_sigma: 0.05,
            straggler_threshold: 1.0,
            cold_fill_fault_rate: 0.0,
        };
        let scheduler = LaunchScheduler::new(&cluster, &registry)
            .with_policy(policy);
        let spec = JobSpec::new("ubuntu:xenial", &["true"], 64);
        let report = scheduler.launch(&mut fabric, &spec).unwrap();
        assert_eq!(report.succeeded(), 64, "stragglers still finish");
        let stragglers = report.stragglers();
        assert!(
            (10..=60).contains(&stragglers),
            "about half must straggle, got {stragglers}"
        );
        assert!(report.retries() >= stragglers as u32 / 2);
        // retried slots resolved against the warm cache on attempt 2
        assert!(report.cache.hits > 0);
    }
}
