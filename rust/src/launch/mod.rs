//! Cluster-scale job launch orchestrator (DESIGN.md S19): the layer that
//! turns per-node simulation into the paper's actual scenario — an
//! `srun`-wide launch where a containerized MPI/GPU application starts on
//! thousands of nodes at once (§III.A, §IV, §V).
//!
//! A [`JobSpec`] names the image, command, node count and GPU/MPI flags; a
//! [`LaunchCluster`] describes the machine as one or more partitions, each
//! with its own `SystemProfile` (heterogeneous GPU generations and MPI ABI
//! versions across partitions); the [`scheduler::LaunchScheduler`] drives
//! the full launch:
//!
//!   1. WLM allocation per partition via `wlm::Slurm` (salloc + srun with
//!      GRES, so CUDA_VISIBLE_DEVICES is injected exactly as §IV.A wants);
//!   2. one coalesced image pull per job through the
//!      `distrib::DistributionFabric` — N nodes, one gateway job;
//!   3. per-node `ShifterRuntime` stage execution, concurrently on a
//!      thread pool, with straggler/retry handling for nodes whose
//!      cold-cache fill misbehaves;
//!   4. aggregation into a [`report::LaunchReport`] with p50/p95/p99 stage
//!      timings, a slowest-node breakdown, queue-wait and fabric dedup
//!      stats — the shape of the paper's §V scaling measurements.

pub mod report;
pub mod scheduler;

pub use report::{LaunchReport, NodeResult, PullSummary};
pub use scheduler::{LaunchError, LaunchScheduler, RetryPolicy};

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::UdiRootConfig;
use crate::hostenv::SystemProfile;
use crate::shifter::{ExtensionRegistry, ShifterRuntime};

/// What the user hands to `shifterimg launch` / the batch system: one
/// containerized job spanning `nodes` compute nodes.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Image reference to launch.
    pub image: String,
    /// Command to run inside every container.
    pub command: Vec<String>,
    /// srun job width — nodes starting the container simultaneously.
    pub nodes: u32,
    /// `--gres=gpu:<N>` per node; 0 disables the GRES request, so the WLM
    /// does not set CUDA_VISIBLE_DEVICES and GPU support stays off (§IV.A).
    pub gpus_per_node: u32,
    /// `--mpi`: activate the §IV.B library swap on every node.
    pub mpi: bool,
    /// Extra launch-environment variables exported on every node (e.g.
    /// `SHIFTER_NET=host` to request the host fabric). WLM-injected
    /// variables (`CUDA_VISIBLE_DEVICES`, SLURM ids) win on conflicts.
    pub env: BTreeMap<String, String>,
    /// Numeric uid of the submitting user (drops privileges to this).
    pub invoking_uid: u32,
    /// Numeric gid of the submitting user.
    pub invoking_gid: u32,
}

impl JobSpec {
    /// A plain CPU job: no GRES, no MPI swap, default credentials.
    pub fn new(image: &str, command: &[&str], nodes: u32) -> JobSpec {
        JobSpec {
            image: image.to_string(),
            command: command.iter().map(|s| s.to_string()).collect(),
            nodes,
            gpus_per_node: 0,
            mpi: false,
            env: BTreeMap::new(),
            invoking_uid: 1000,
            invoking_gid: 1000,
        }
    }

    /// Request `--gres=gpu:<per_node>` on every node.
    pub fn with_gpus(mut self, per_node: u32) -> JobSpec {
        self.gpus_per_node = per_node;
        self
    }

    /// Activate the §IV.B MPI library swap on every node.
    pub fn with_mpi(mut self) -> JobSpec {
        self.mpi = true;
        self
    }

    /// Export one launch-environment variable on every node of the job
    /// (extension triggers like `SHIFTER_NET`, `SHIFTER_NET_FALLBACK`).
    pub fn with_env(mut self, k: &str, v: &str) -> JobSpec {
        self.env.insert(k.to_string(), v.to_string());
        self
    }
}

/// A contiguous range of identical nodes sharing one `SystemProfile`.
///
/// The stored profile is *padded*: its `nodes` vector covers every global
/// node id up to the end of the partition, so `profile.driver(global_id)`
/// resolves for any node the partition owns — the runtime receives global
/// ids and the fabric keys its per-node caches on them.
#[derive(Debug, Clone)]
pub struct Partition {
    name: String,
    first_node: u32,
    node_count: u32,
    profile: Arc<SystemProfile>,
}

impl Partition {
    /// Partition name (e.g. `daint-xc50`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// First global node id the partition owns.
    pub fn first_node(&self) -> u32 {
        self.first_node
    }

    /// Number of nodes in the partition.
    pub fn node_count(&self) -> u32 {
        self.node_count
    }

    /// Whether global node id `node` belongs to this partition.
    pub fn contains(&self, node: u32) -> bool {
        node >= self.first_node && node < self.first_node + self.node_count
    }

    /// The partition's (padded) system profile.
    pub fn profile(&self) -> &SystemProfile {
        &self.profile
    }

    /// Shared handle to the profile, for runtimes on worker threads.
    pub fn shared_profile(&self) -> Arc<SystemProfile> {
        Arc::clone(&self.profile)
    }

    /// A runtime for this partition: configured with the site
    /// `udiRoot.conf` when one is given, else with the stock per-profile
    /// config — the single wiring point the launch scheduler and the
    /// `Site` facade share.
    pub fn runtime(&self, config: Option<&UdiRootConfig>) -> ShifterRuntime {
        match config {
            Some(c) => ShifterRuntime::shared_with_config(
                self.shared_profile(),
                c.clone(),
            ),
            None => ShifterRuntime::shared(self.shared_profile()),
        }
    }

    /// [`Partition::runtime`] with an explicit host-extension registry —
    /// the wiring point `SiteBuilder::with_extension` reaches node
    /// execution through.
    pub fn runtime_with_extensions(
        &self,
        config: Option<&UdiRootConfig>,
        extensions: Arc<ExtensionRegistry>,
    ) -> ShifterRuntime {
        self.runtime(config).with_extensions(extensions)
    }
}

/// The whole machine a job launches onto: partitions in node-id order.
#[derive(Debug, Clone, Default)]
pub struct LaunchCluster {
    partitions: Vec<Partition>,
    total_nodes: u32,
}

impl LaunchCluster {
    /// Empty cluster; add partitions with [`Self::with_partition`].
    pub fn new() -> LaunchCluster {
        LaunchCluster::default()
    }

    /// Append a partition of `nodes` identical nodes modeled on `base`
    /// (the base profile's first node spec is replicated; its software
    /// environment — driver version, host MPI, kernel — carries over).
    pub fn with_partition(
        mut self,
        name: &str,
        base: &SystemProfile,
        nodes: u32,
    ) -> LaunchCluster {
        assert!(nodes >= 1, "a partition needs at least one node");
        let first_node = self.total_nodes;
        let mut profile = base.clone();
        let Some(spec) = profile.nodes.first().cloned() else {
            panic!("partition {name:?}: base profile has no node spec");
        };
        profile.nodes = vec![spec; (first_node + nodes) as usize];
        self.partitions.push(Partition {
            name: name.to_string(),
            first_node,
            node_count: nodes,
            profile: Arc::new(profile),
        });
        self.total_nodes += nodes;
        self
    }

    /// Single-partition cluster: `nodes` identical nodes modeled on `base`.
    pub fn homogeneous(base: &SystemProfile, nodes: u32) -> LaunchCluster {
        LaunchCluster::new().with_partition(base.name, base, nodes)
    }

    /// The stock heterogeneous split as `(name, profile, nodes)` triples
    /// — the single source of truth [`LaunchCluster::daint_linux_split`]
    /// and `SiteBuilder::hetero_daint_linux` share: half Piz Daint (P100,
    /// driver 375.66, Cray MPT), half Linux Cluster (K40m/K80, driver
    /// 367.48, MVAPICH2).
    pub fn daint_linux_partitions(
        nodes: u32,
    ) -> [(&'static str, SystemProfile, u32); 2] {
        let daint_share = nodes / 2;
        [
            ("daint-xc50", SystemProfile::piz_daint(), daint_share),
            (
                "linux-cluster",
                SystemProfile::linux_cluster(),
                nodes - daint_share,
            ),
        ]
    }

    /// The stock heterogeneous machine built from
    /// [`LaunchCluster::daint_linux_partitions`] (panics below 2 nodes;
    /// the `Site` facade surfaces the same condition as a typed error).
    pub fn daint_linux_split(nodes: u32) -> LaunchCluster {
        assert!(nodes >= 2, "a two-partition split needs at least 2 nodes");
        let mut cluster = LaunchCluster::new();
        for (name, profile, share) in Self::daint_linux_partitions(nodes) {
            cluster = cluster.with_partition(name, &profile, share);
        }
        cluster
    }

    /// Total nodes across all partitions.
    pub fn total_nodes(&self) -> u32 {
        self.total_nodes
    }

    /// The partitions in global node-id order.
    pub fn partitions(&self) -> &[Partition] {
        &self.partitions
    }

    /// The partition owning global node id `node`, if any.
    pub fn partition_of(&self, node: u32) -> Option<&Partition> {
        self.partitions.iter().find(|p| p.contains(node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_tile_the_node_space() {
        let cluster = LaunchCluster::new()
            .with_partition("gpu", &SystemProfile::piz_daint(), 8)
            .with_partition("cpu", &SystemProfile::linux_cluster(), 4);
        assert_eq!(cluster.total_nodes(), 12);
        assert_eq!(cluster.partitions().len(), 2);
        assert_eq!(cluster.partition_of(0).unwrap().name(), "gpu");
        assert_eq!(cluster.partition_of(7).unwrap().name(), "gpu");
        assert_eq!(cluster.partition_of(8).unwrap().name(), "cpu");
        assert_eq!(cluster.partition_of(11).unwrap().name(), "cpu");
        assert!(cluster.partition_of(12).is_none());
    }

    #[test]
    fn padded_profile_resolves_global_node_ids() {
        let cluster = LaunchCluster::new()
            .with_partition("a", &SystemProfile::piz_daint(), 3)
            .with_partition("b", &SystemProfile::linux_cluster(), 3);
        let b = cluster.partition_of(5).unwrap();
        // a global id inside partition b resolves against b's profile,
        // with b's driver generation — not a's
        let driver = b.profile().driver(5).expect("driver for global id");
        assert_eq!(driver.version, (367, 48));
        assert_eq!(driver.cuda_device_count(), 3);
        let a = cluster.partition_of(2).unwrap();
        assert_eq!(a.profile().driver(2).unwrap().version, (375, 66));
    }

    #[test]
    fn homogeneous_cluster_scales_past_the_base_profile() {
        // piz_daint models 384 hybrid nodes; the launch cluster can
        // replicate the node spec out to storm scale
        let cluster = LaunchCluster::homogeneous(&SystemProfile::piz_daint(), 4096);
        assert_eq!(cluster.total_nodes(), 4096);
        let p = cluster.partition_of(4095).unwrap();
        assert!(p.profile().driver(4095).is_some());
    }
}
