//! Launch aggregation (DESIGN.md S19): per-node outcomes rolled up into
//! the percentile report the paper's §V scaling measurements are shaped
//! like — p50/p95/p99 per runtime stage, slowest-node breakdown, pull
//! queue-wait, and the distribution fabric's cache/dedup accounting.

use crate::distrib::CacheStats;
use crate::metrics::{Stats, Table};
use crate::shifter::Stage;
use crate::util::json::Json;

/// One node slot's outcome.
#[derive(Debug, Clone)]
pub struct NodeResult {
    /// Global node id.
    pub node: u32,
    /// Partition the node belongs to.
    pub partition: String,
    /// Launch attempts consumed (1 = clean first try; 0 = never ran
    /// because WLM allocation or preflight already failed the slot).
    pub attempts: u32,
    /// The slot exceeded the straggler threshold at least once.
    pub straggler: bool,
    /// Runtime overhead across all attempts, jitter included.
    pub total_secs: f64,
    /// (stage name, simulated seconds) of the final successful attempt.
    pub stage_secs: Vec<(&'static str, f64)>,
    /// Versioned driver libraries injected on this node — the per-node
    /// driver stack (differs across heterogeneous partitions).
    pub gpu_libraries: Vec<String>,
    /// Host MPI the container was swapped to, when `--mpi` succeeded.
    pub host_mpi: Option<String>,
    /// Host extensions that injected on this node, in registry order
    /// (`"gpu"`, `"mpi"`, `"net"`, plus any site-defined extension).
    pub extensions: Vec<&'static str>,
    /// Why the slot failed; None = the container launched.
    pub error: Option<String>,
}

impl NodeResult {
    /// True when the container launched on this slot.
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// The coalesced gateway pull backing the whole job.
#[derive(Debug, Clone, Copy)]
pub struct PullSummary {
    /// How long the job sat in the shard queue before its worker started.
    pub queue_wait_secs: f64,
    /// Enqueue-to-READY latency of the shared job.
    pub turnaround_secs: f64,
    /// Nodes absorbed into the one job (the dedup width).
    pub requesters: usize,
    /// Pull jobs that exist across all gateway shards of the fabric —
    /// launch-scale coalescing holds when this equals the number of
    /// distinct image references ever pulled.
    pub jobs_total: usize,
}

/// What `shifterimg launch` prints and `benches/launch_scale.rs` asserts.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    /// Image the job launched.
    pub image: String,
    /// Job width the spec asked for.
    pub nodes_requested: u32,
    /// Per-slot outcomes, in plan order: ascending global node id for
    /// [`crate::launch::LaunchScheduler::launch`]; for
    /// [`crate::launch::LaunchScheduler::launch_on`], the caller's node
    /// order grouped by partition.
    pub node_results: Vec<NodeResult>,
    /// None when every slot died before the pull phase.
    pub pull: Option<PullSummary>,
    /// Node-cache counters across the fabric after the launch.
    pub cache: CacheStats,
    /// Content-store dedup ratio after the launch.
    pub cas_dedup_ratio: f64,
}

impl LaunchReport {
    /// Slots whose container launched.
    pub fn succeeded(&self) -> usize {
        self.node_results.iter().filter(|r| r.ok()).count()
    }

    /// Slots that failed (WLM, preflight, pull or container errors).
    pub fn failed(&self) -> usize {
        self.node_results.len() - self.succeeded()
    }

    /// Extra attempts beyond the first, summed over all slots.
    pub fn retries(&self) -> u32 {
        self.node_results
            .iter()
            .map(|r| r.attempts.saturating_sub(1))
            .sum()
    }

    /// Slots that exceeded the straggler threshold at least once.
    pub fn stragglers(&self) -> usize {
        self.node_results.iter().filter(|r| r.straggler).count()
    }

    /// Distribution of per-node launch totals over successful slots.
    pub fn total_stats(&self) -> Option<Stats> {
        let samples: Vec<f64> = self
            .node_results
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.total_secs)
            .collect();
        if samples.is_empty() {
            None
        } else {
            Some(Stats::from_samples(&samples))
        }
    }

    /// Per-stage timing distribution over successful slots, in §III.A
    /// stage order.
    pub fn stage_stats(&self) -> Vec<(&'static str, Stats)> {
        Stage::ORDER
            .iter()
            .filter_map(|stage| {
                let samples: Vec<f64> = self
                    .node_results
                    .iter()
                    .filter(|r| r.ok())
                    .filter_map(|r| {
                        r.stage_secs
                            .iter()
                            .find(|(name, _)| *name == stage.name())
                            .map(|(_, secs)| *secs)
                    })
                    .collect();
                if samples.is_empty() {
                    None
                } else {
                    Some((stage.name(), Stats::from_samples(&samples)))
                }
            })
            .collect()
    }

    /// The `k` slowest successful slots, slowest first.
    pub fn slowest(&self, k: usize) -> Vec<&NodeResult> {
        let mut ok: Vec<&NodeResult> =
            self.node_results.iter().filter(|r| r.ok()).collect();
        ok.sort_by(|a, b| b.total_secs.total_cmp(&a.total_secs));
        ok.truncate(k);
        ok
    }

    /// Nodes per activated host extension across successful slots, in
    /// first-seen order — the aggregated `ExtensionReport` view of the
    /// whole launch.
    pub fn extension_counts(&self) -> Vec<(&'static str, usize)> {
        let mut out: Vec<(&'static str, usize)> = Vec::new();
        for r in self.node_results.iter().filter(|r| r.ok()) {
            for ext in &r.extensions {
                match out.iter_mut().find(|(name, _)| name == ext) {
                    Some((_, n)) => *n += 1,
                    None => out.push((*ext, 1)),
                }
            }
        }
        out
    }

    /// Distinct failure reasons with their node counts (deduplicated so a
    /// 4096-node report stays readable).
    pub fn failure_summary(&self) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> = Vec::new();
        for r in &self.node_results {
            let Some(err) = &r.error else { continue };
            match out.iter_mut().find(|(msg, _)| msg == err) {
                Some((_, n)) => *n += 1,
                None => out.push((err.clone(), 1)),
            }
        }
        out
    }

    /// Render the report the way the paper-table benches do.
    pub fn render(&self) -> String {
        let fmt_secs = |v: f64| -> String {
            if v < 1.0 {
                format!("{:.2}ms", v * 1e3)
            } else {
                format!("{v:.2}s")
            }
        };
        let mut out = String::new();
        let mut table = Table::new(
            &format!(
                "launch {} on {} nodes ({} ok, {} failed)",
                self.image,
                self.nodes_requested,
                self.succeeded(),
                self.failed()
            ),
            &["stage", "p50", "p95", "p99", "worst"],
        );
        for (name, stats) in self.stage_stats() {
            table.row(&[
                name.to_string(),
                fmt_secs(stats.p50),
                fmt_secs(stats.p95),
                fmt_secs(stats.p99),
                fmt_secs(stats.worst),
            ]);
        }
        if let Some(total) = self.total_stats() {
            table.row(&[
                "TOTAL".to_string(),
                fmt_secs(total.p50),
                fmt_secs(total.p95),
                fmt_secs(total.p99),
                fmt_secs(total.worst),
            ]);
        }
        out.push_str(&table.render());
        if let Some(pull) = &self.pull {
            out.push_str(&format!(
                "pull: 1 coalesced job for {} requesters ({} job(s) on the \
                 fabric), queue wait {}, turnaround {}\n",
                pull.requesters,
                pull.jobs_total,
                fmt_secs(pull.queue_wait_secs),
                fmt_secs(pull.turnaround_secs),
            ));
        }
        let ext_counts = self.extension_counts();
        if !ext_counts.is_empty() {
            let parts: Vec<String> = ext_counts
                .iter()
                .map(|(name, n)| format!("{name} on {n} node(s)"))
                .collect();
            out.push_str(&format!("extensions: {}\n", parts.join(", ")));
        }
        out.push_str(&format!(
            "retries: {} ({} straggler slot(s)); node caches: {} hits / {} \
             misses / {} evictions on {} nodes; cas dedup {:.2}x\n",
            self.retries(),
            self.stragglers(),
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.nodes,
            self.cas_dedup_ratio,
        ));
        for r in self.slowest(3) {
            let breakdown: Vec<String> = r
                .stage_secs
                .iter()
                .filter(|(_, secs)| *secs > 1e-4)
                .map(|(name, secs)| format!("{name} {}", fmt_secs(*secs)))
                .collect();
            out.push_str(&format!(
                "slowest: node {} [{}] {} in {} attempt(s) ({})\n",
                r.node,
                r.partition,
                fmt_secs(r.total_secs),
                r.attempts,
                breakdown.join(", "),
            ));
        }
        for (err, n) in self.failure_summary() {
            out.push_str(&format!("failed: {n} node(s): {err}\n"));
        }
        out
    }

    /// JSON shape for `BENCH_launch.json` (the CI bench-smoke artifact).
    pub fn to_json(&self) -> Json {
        let stages: Vec<Json> = self
            .stage_stats()
            .iter()
            .map(|(name, s)| {
                Json::obj(vec![
                    ("stage", Json::str(*name)),
                    ("p50_secs", Json::Num(s.p50)),
                    ("p95_secs", Json::Num(s.p95)),
                    ("p99_secs", Json::Num(s.p99)),
                    ("worst_secs", Json::Num(s.worst)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("image", Json::str(self.image.as_str())),
            ("nodes_requested", Json::Num(self.nodes_requested as f64)),
            ("succeeded", Json::Num(self.succeeded() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("retries", Json::Num(f64::from(self.retries()))),
            ("stragglers", Json::Num(self.stragglers() as f64)),
            ("cache_hits", Json::Num(self.cache.hits as f64)),
            ("cache_misses", Json::Num(self.cache.misses as f64)),
            ("cas_dedup_ratio", Json::Num(self.cas_dedup_ratio)),
            ("stages", Json::Arr(stages)),
            (
                "extensions",
                Json::Arr(
                    self.extension_counts()
                        .iter()
                        .map(|&(name, n)| {
                            Json::obj(vec![
                                ("extension", Json::str(name)),
                                ("nodes", Json::Num(n as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some(total) = self.total_stats() {
            fields.push((
                "total",
                Json::obj(vec![
                    ("p50_secs", Json::Num(total.p50)),
                    ("p95_secs", Json::Num(total.p95)),
                    ("p99_secs", Json::Num(total.p99)),
                    ("worst_secs", Json::Num(total.worst)),
                ]),
            ));
        }
        if let Some(pull) = &self.pull {
            fields.push((
                "pull",
                Json::obj(vec![
                    ("queue_wait_secs", Json::Num(pull.queue_wait_secs)),
                    ("turnaround_secs", Json::Num(pull.turnaround_secs)),
                    ("requesters", Json::Num(pull.requesters as f64)),
                    ("jobs_total", Json::Num(pull.jobs_total as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(node: u32, secs: f64, err: Option<&str>) -> NodeResult {
        NodeResult {
            node,
            partition: "p".to_string(),
            attempts: 1,
            straggler: false,
            total_secs: secs,
            stage_secs: vec![
                ("resolve-image", 1e-4),
                ("prepare-environment", secs - 1e-4),
            ],
            gpu_libraries: vec![],
            host_mpi: None,
            extensions: vec!["gpu"],
            error: err.map(|e| e.to_string()),
        }
    }

    fn report(results: Vec<NodeResult>) -> LaunchReport {
        LaunchReport {
            image: "ubuntu:xenial".to_string(),
            nodes_requested: results.len() as u32,
            node_results: results,
            pull: Some(PullSummary {
                queue_wait_secs: 0.5,
                turnaround_secs: 9.0,
                requesters: 4,
                jobs_total: 1,
            }),
            cache: CacheStats::default(),
            cas_dedup_ratio: 1.0,
        }
    }

    #[test]
    fn counts_and_percentiles() {
        let rep = report(vec![
            result(0, 1.0, None),
            result(1, 2.0, None),
            result(2, 4.0, None),
            result(3, 1.0, Some("boom")),
        ]);
        assert_eq!(rep.succeeded(), 3);
        assert_eq!(rep.failed(), 1);
        let total = rep.total_stats().unwrap();
        assert_eq!(total.n, 3);
        assert_eq!(total.worst, 4.0);
        assert!(total.p99 >= total.p50);
        let stages = rep.stage_stats();
        assert_eq!(stages[0].0, "resolve-image");
        assert_eq!(stages.len(), 2);
        let slowest = rep.slowest(2);
        assert_eq!(slowest[0].node, 2);
        assert_eq!(slowest.len(), 2);
        assert_eq!(rep.failure_summary(), vec![("boom".to_string(), 1)]);
        // only the 3 successful slots count toward the aggregation
        assert_eq!(rep.extension_counts(), vec![("gpu", 3)]);
    }

    #[test]
    fn render_and_json_carry_the_story() {
        let rep = report(vec![result(0, 1.0, None), result(1, 2.0, None)]);
        let text = rep.render();
        assert!(text.contains("launch ubuntu:xenial on 2 nodes"));
        assert!(text.contains("p99"));
        assert!(text.contains("coalesced job"));
        assert!(text.contains("extensions: gpu on 2 node(s)"));
        let json = rep.to_json();
        assert_eq!(json.get("succeeded").unwrap().as_u64(), Some(2));
        let exts = json.get("extensions").unwrap().as_arr().unwrap();
        assert_eq!(exts[0].get("nodes").and_then(|v| v.as_u64()), Some(2));
        assert_eq!(
            json.at(&["pull", "jobs_total"]).unwrap().as_u64(),
            Some(1)
        );
        // round-trips through the parser (the CI artifact is consumable)
        let back = Json::parse(&json.to_string()).unwrap();
        assert_eq!(back.get("image").unwrap().as_str(), Some("ubuntu:xenial"));
    }

    #[test]
    fn all_failed_report_has_no_totals() {
        let rep = report(vec![result(0, 1.0, Some("dead"))]);
        assert!(rep.total_stats().is_none());
        assert!(rep.stage_stats().is_empty());
        assert_eq!(rep.failed(), 1);
        assert!(rep.render().contains("dead"));
    }
}
