//! OSU latency walkthrough (§V.C.1): the same three OSU containers
//! (A: MPICH 3.1.4, B: MVAPICH2 2.2, C: Intel MPI 2017) deployed on both
//! HPC systems — each declared as a `Site` — with Shifter MPI support
//! enabled and disabled, against the native baseline: the mechanism
//! behind Tables III and IV.
//!
//! Run: `cargo run --release --example osu_latency`

use shifter_rs::apps::osu;
use shifter_rs::fabric::OSU_SIZES;
use shifter_rs::shifter::RunOptions;
use shifter_rs::{Site, SystemProfile};

const CONTAINERS: [(&str, &str); 3] = [
    ("A (MPICH 3.1.4)", "osu-benchmarks:mpich-3.1.4"),
    ("B (MVAPICH2 2.2)", "osu-benchmarks:mvapich2-2.2"),
    ("C (Intel MPI 2017)", "osu-benchmarks:intelmpi-2017.1"),
];

fn main() -> Result<(), Box<dyn std::error::Error>> {
    for profile in [SystemProfile::linux_cluster(), SystemProfile::piz_daint()] {
        println!(
            "== {} — native {} over {} ==",
            profile.name,
            profile.host_mpi.version_string(),
            profile.fabric.name()
        );
        let mut site = Site::builder()
            .profile(profile.clone())
            .nodes(2)
            .gateway_shards(1)
            .build()?;
        for (_, image) in CONTAINERS {
            site.pull(image)?;
        }
        let native = osu::run_native(&profile);

        for (label, image) in CONTAINERS {
            // enabled: shifter --mpi
            let c_on = site
                .run(&RunOptions::new(image, &["osu_latency"]).with_mpi())?;
            let on = osu::run_container(&profile, &c_on, &format!("{image}-on"));
            // disabled: no --mpi flag, container keeps its own MPI
            let c_off = site.run(&RunOptions::new(image, &["osu_latency"]))?;
            let off =
                osu::run_container(&profile, &c_off, &format!("{image}-off"));

            println!("\ncontainer {label}:");
            println!(
                "  swap: {}",
                c_on.mpi
                    .as_ref()
                    .map(|m| format!("{} -> {}", m.container_mpi, m.host_mpi))
                    .unwrap_or_default()
            );
            println!("  {:>6} {:>10} {:>10} {:>10}", "size", "native µs", "on/nat", "off/nat");
            for (i, &size) in OSU_SIZES.iter().enumerate() {
                println!(
                    "  {:>6} {:>10.2} {:>10.2} {:>10.2}",
                    osu::size_label(size),
                    native[i].best_us,
                    on[i].best_us / native[i].best_us,
                    off[i].best_us / native[i].best_us,
                );
            }
        }
        println!();
    }
    Ok(())
}
