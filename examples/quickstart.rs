//! Quickstart — the paper's §III.B end-user workflow, end to end, on the
//! `Site` facade (DESIGN.md S21). A site operator declares the system
//! once with `SiteBuilder`; every user workflow then goes through the
//! one typed handle:
//!
//!   1. `shifterimg pull docker:ubuntu:xenial`  →  `site.pull(..)`
//!   2. `shifter --image=ubuntu:xenial cat /etc/os-release`  →
//!      `site.run(..)`
//!   3. a CUDA container with GPU support triggered via
//!      `CUDA_VISIBLE_DEVICES`, showing device renumbering,
//!   4. an MPI container with the §IV.B library swap, and
//!   5. one cluster-scale launch across all four nodes  →
//!      `site.launch(..)`.
//!
//! Run: `cargo run --release --example quickstart`

use shifter_rs::shifter::RunOptions;
use shifter_rs::{JobSpec, Site, SystemProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let daint = SystemProfile::piz_daint();
    println!("host system : {} ({}, kernel {})", daint.name, daint.os, daint.kernel);
    println!("host MPI    : {}", daint.host_mpi.version_string());
    println!("fabric      : {}\n", daint.fabric.name());

    // -- 0. the site operator wires the stack exactly once ---------------
    let mut site = Site::builder()
        .profile(daint.clone())
        .nodes(4)
        .gateway_shards(2)
        .build()?;

    // -- 1. pull ----------------------------------------------------------
    for image in ["docker:ubuntu:xenial", "nvidia/cuda-image:8.0", "osu-benchmarks:mpich-3.1.4"] {
        let pull = site.pull(image)?;
        println!(
            "shifterimg pull {image}: {:.1}s (download {:.1}s, squashfs {:.1}s)",
            pull.turnaround_secs, pull.download_secs, pull.convert_secs
        );
    }
    println!("\nshifterimg images:");
    for i in site.images() {
        println!("  {i}");
    }

    // -- 2. the paper's os-release example --------------------------------
    println!("\n$ shifter --image=ubuntu:xenial cat /etc/os-release");
    let c = site.run(&RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]))?;
    print!("{}", c.exec(&["cat", "/etc/os-release"])?);
    println!(
        "(container start-up overhead: {:.1} ms)\n",
        c.startup_overhead_secs() * 1e3
    );

    // -- 3. GPU support ----------------------------------------------------
    println!("$ export CUDA_VISIBLE_DEVICES=0");
    println!("$ shifter --image=cuda-image ./deviceQuery");
    let c = site.run(
        &RunOptions::new("nvidia/cuda-image:8.0", &["./deviceQuery"])
            .with_env("CUDA_VISIBLE_DEVICES", "0"),
    )?;
    let gpu = c.gpu.as_ref().expect("GPU support triggered");
    for (cid, board) in gpu
        .container_devices
        .iter()
        .zip(c.visible_gpus(&daint, 0))
    {
        println!(
            "  Device {cid}: \"{}\" (cc {}.{}, {} GiB, {:.0} GF/s fp64 peak)",
            board.name,
            board.arch.compute_capability().0,
            board.arch.compute_capability().1,
            board.mem_gib,
            board.fp64_gflops_peak,
        );
    }
    println!(
        "  driver libraries injected: {} (libcuda, nvidia-ml, …)",
        gpu.libraries.len()
    );
    println!("  host devices {:?} -> container devices {:?}\n", gpu.host_devices, gpu.container_devices);

    // -- 4. MPI swap ----------------------------------------------------------
    println!("$ srun -n 2 --mpi=pmi2 shifter --mpi --image=mpich-image osu_latency");
    let c = site.run(
        &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"]).with_mpi(),
    )?;
    let mpi = c.mpi.as_ref().expect("MPI support activated");
    println!("  container MPI : {}", mpi.container_mpi);
    println!("  host MPI      : {} (swapped in)", mpi.host_mpi);
    for (cpath, hpath) in &mpi.swapped {
        println!("    {cpath}  <-  {hpath}");
    }
    println!("  + {} transport dependencies, {} config files", mpi.dependencies.len(), mpi.config_files.len());

    println!("\nstage log of the last run:");
    print!("{}", c.stage_log.render());

    // -- 5. one cluster-scale job across the whole site ----------------------
    println!("\n$ shifterimg --nodes=4 launch ubuntu:xenial true");
    let report = site.launch(&JobSpec::new("ubuntu:xenial", &["true"], 4))?;
    let total = report.total_stats().expect("launch totals");
    println!(
        "  {} / {} nodes up, one coalesced pull for {} requesters, p99 start-up {:.1} ms",
        report.succeeded(),
        report.nodes_requested,
        report.pull.as_ref().map_or(0, |p| p.requesters),
        total.p99 * 1e3,
    );
    Ok(())
}
