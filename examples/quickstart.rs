//! Quickstart — the paper's §III.B end-user workflow, end to end:
//!
//!   1. `shifterimg pull docker:ubuntu:xenial`
//!   2. `shifter --image=ubuntu:xenial cat /etc/os-release`
//!   3. a CUDA container with GPU support triggered via
//!      `CUDA_VISIBLE_DEVICES`, showing device renumbering, and
//!   4. an MPI container with the §IV.B library swap.
//!
//! Run: `cargo run --release --example quickstart`

use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let daint = SystemProfile::piz_daint();
    println!("host system : {} ({}, kernel {})", daint.name, daint.os, daint.kernel);
    println!("host MPI    : {}", daint.host_mpi.version_string());
    println!("fabric      : {}\n", daint.fabric.name());

    // -- 1. pull --------------------------------------------------------
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(daint.pfs.clone().unwrap());
    for image in ["docker:ubuntu:xenial", "nvidia/cuda-image:8.0", "osu-benchmarks:mpich-3.1.4"] {
        let rep = gateway.pull(&registry, image)?;
        println!(
            "shifterimg pull {image}: {:.1}s (download {:.1}s, squashfs {:.1}s)",
            rep.total_secs(),
            rep.download_secs,
            rep.convert_secs
        );
    }
    println!("\nshifterimg images:");
    for i in gateway.list() {
        println!("  {i}");
    }

    // -- 2. the paper's os-release example --------------------------------
    let runtime = ShifterRuntime::new(&daint);
    println!("\n$ shifter --image=ubuntu:xenial cat /etc/os-release");
    let c = runtime.run(
        &gateway,
        &RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]),
    )?;
    print!("{}", c.exec(&["cat", "/etc/os-release"])?);
    println!(
        "(container start-up overhead: {:.1} ms)\n",
        c.startup_overhead_secs() * 1e3
    );

    // -- 3. GPU support ----------------------------------------------------
    println!("$ export CUDA_VISIBLE_DEVICES=0");
    println!("$ shifter --image=cuda-image ./deviceQuery");
    let c = runtime.run(
        &gateway,
        &RunOptions::new("nvidia/cuda-image:8.0", &["./deviceQuery"])
            .with_env("CUDA_VISIBLE_DEVICES", "0"),
    )?;
    let gpu = c.gpu.as_ref().expect("GPU support triggered");
    for (cid, board) in gpu
        .container_devices
        .iter()
        .zip(c.visible_gpus(&daint, 0))
    {
        println!(
            "  Device {cid}: \"{}\" (cc {}.{}, {} GiB, {:.0} GF/s fp64 peak)",
            board.name,
            board.arch.compute_capability().0,
            board.arch.compute_capability().1,
            board.mem_gib,
            board.fp64_gflops_peak,
        );
    }
    println!(
        "  driver libraries injected: {} (libcuda, nvidia-ml, …)",
        gpu.libraries.len()
    );
    println!("  host devices {:?} -> container devices {:?}\n", gpu.host_devices, gpu.container_devices);

    // -- 4. MPI swap ----------------------------------------------------------
    println!("$ srun -n 2 --mpi=pmi2 shifter --mpi --image=mpich-image osu_latency");
    let c = runtime.run(
        &gateway,
        &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"]).with_mpi(),
    )?;
    let mpi = c.mpi.as_ref().expect("MPI support activated");
    println!("  container MPI : {}", mpi.container_mpi);
    println!("  host MPI      : {} (swapped in)", mpi.host_mpi);
    for (cpath, hpath) in &mpi.swapped {
        println!("    {cpath}  <-  {hpath}");
    }
    println!("  + {} transport dependencies, {} config files", mpi.dependencies.len(), mpi.config_files.len());

    println!("\nstage log of the last run:");
    print!("{}", c.stage_log.render());
    Ok(())
}
