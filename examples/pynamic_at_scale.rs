//! Pynamic at scale (§V.C.3 / Fig. 3) — the full deployment story for a
//! >3000-process Python application on Piz Daint, declared as a 256-node
//! `Site` and using the asynchronous pull lifecycle plus the ALPS
//! workload manager:
//!
//!   1. `shifterimg pull pynamic:1.3` goes through the gateway daemon's
//!      job lifecycle (ENQUEUED → PULLING → … → READY), driven via
//!      `site.request` / `site.tick` / `site.pull_status`;
//!   2. ALPS places 3072 ranks (256 nodes × 12);
//!   3. every node starts the same loop-mounted container;
//!   4. the import storm that crushes the Lustre MDS natively is served
//!      from the node-local squashfs mounts.
//!
//! Run: `cargo run --release --example pynamic_at_scale`

use shifter_rs::apps::pynamic::{self, Mode};
use shifter_rs::gateway::PullState;
use shifter_rs::shifter::{preflight, RunOptions};
use shifter_rs::wlm::{Alps, AprunRequest};
use shifter_rs::{Site, SystemProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let daint = SystemProfile::piz_daint();

    // kernel preflight: the old-kernel compatibility design goal
    let pf = preflight::preflight(&daint);
    println!(
        "preflight on {} (kernel {}): {} requirements satisfied, ok = {}",
        daint.name,
        daint.kernel,
        pf.satisfied.len(),
        pf.ok()
    );

    let mut site = Site::builder()
        .profile(daint.clone())
        .nodes(256)
        .gateway_shards(1)
        .build()?;

    // -- 1. async pull through the gateway daemon -------------------------
    site.request("pynamic:1.3", "cscs-user")?;
    println!("\nshifterimg pull pynamic:1.3 (async):");
    let mut last = PullState::Enqueued;
    while !site.pull_status("pynamic:1.3").unwrap().state.terminal() {
        site.tick(2.0);
        let st = site.pull_status("pynamic:1.3").unwrap().state;
        if st != last {
            println!(
                "  t={:>5.0}s  {}",
                site.fabric().cluster().now(),
                st.name()
            );
            last = st;
        }
    }

    // -- 2. ALPS placement --------------------------------------------------
    let mut alps = Alps::new(&daint);
    let ranks = alps.aprun(AprunRequest {
        ranks: 3072,
        per_node: 12,
        gpus: false,
    })?;
    let nodes = ranks.iter().map(|r| r.node).max().unwrap() + 1;
    println!("\naprun -n 3072 -N 12: {} ranks on {} nodes", ranks.len(), nodes);

    // -- 3. one container start per node ------------------------------------
    let mut opts = RunOptions::new("pynamic:1.3", &["./pynamic-pyMPI"]);
    opts.env = ranks[0].env.clone();
    opts.concurrent_nodes = nodes;
    let container = site.run(&opts)?;
    println!(
        "container environment on each node: {} mounts, start-up {:.0} ms \
         (incl. image fetch shared by {} nodes)",
        container.mounts.len(),
        container.startup_overhead_secs() * 1e3,
        nodes
    );
    assert!(container
        .rootfs
        .is_dir("/opt/pynamic/modules"));

    // -- 4. the Fig. 3 comparison -------------------------------------------
    println!("\nPynamic phases at 3072 ranks (mean of 30 runs):");
    for (label, mode) in [("native on Lustre", Mode::Native), ("Shifter", Mode::Shifter)] {
        let r = pynamic::run(&daint, 3072, mode);
        println!(
            "  {label:<18} startup {:>7.1}s  import {:>7.1}s  visit {:>4.1}s  total {:>7.1}s",
            r.startup.mean,
            r.import.mean,
            r.visit.mean,
            r.total_mean()
        );
    }
    let nat = pynamic::run(&daint, 3072, Mode::Native);
    let shf = pynamic::run(&daint, 3072, Mode::Shifter);
    println!(
        "\nShifter deploys the 3072-process Python app {:.0}x faster ✓",
        nat.total_mean() / shf.total_mean()
    );
    Ok(())
}
