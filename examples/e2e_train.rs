//! End-to-end driver (DESIGN.md's required full-stack validation):
//!
//!   laptop: build the TensorFlow image  →  push to the registry
//!   Piz Daint: the site (declared once via `SiteBuilder`, resolving
//!   against that registry) pulls the image  →  SLURM allocates a hybrid
//!   node with `--gres=gpu:1` (GRES sets CUDA_VISIBLE_DEVICES)  →
//!   `site.run` prepares the container with GPU support  →  the
//!   containerized trainer runs REAL training steps through the
//!   AOT-compiled `mnist_train` artifact on the PJRT CPU client, logging
//!   the loss curve.
//!
//! The same artifact is then executed "natively" (no container) and the
//! two loss curves are compared bit-for-bit — the paper's portability
//! claim (same bits, native performance) made concrete.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train [steps]`

use shifter_rs::apps::tf_trainer::{self, TfWorkload};
use shifter_rs::gpu::GpuModel;
use shifter_rs::runtime::Executor;
use shifter_rs::shifter::RunOptions;
use shifter_rs::wlm::{GresRequest, Slurm};
use shifter_rs::{Registry, Site, SystemProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: u32 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // ---- workstation side: build + push --------------------------------
    println!("== laptop: docker build + docker push ==");
    let image = shifter_rs::image::builder::tensorflow_image();
    println!(
        "built {} ({} layers, {:.1} MiB transfer)",
        image.reference.canonical(),
        image.layers.len(),
        image.transfer_bytes() as f64 / (1024.0 * 1024.0)
    );
    let mut registry = Registry::dockerhub();
    registry.push(image);

    // ---- HPC side: one site, wired against that registry -----------------
    println!("\n== Piz Daint: shifterimg pull ==");
    let daint = SystemProfile::piz_daint();
    let mut site = Site::builder()
        .profile(daint.clone())
        .nodes(1)
        .registry(registry)
        .build()?;
    let pull = site.pull("tensorflow/tensorflow:1.0.0-devel-gpu-py3")?;
    println!(
        "pulled in {:.1}s (download {:.1}s, expand {:.1}s, squashfs {:.1}s, store {:.1}s)",
        pull.turnaround_secs,
        pull.download_secs,
        pull.expand_secs,
        pull.convert_secs,
        pull.store_secs
    );

    // ---- SLURM: allocate a hybrid node with one GPU ----------------------
    let mut slurm = Slurm::new(&daint);
    let alloc = slurm.salloc(1)?;
    let ranks = slurm.srun(&alloc, 1, Some(GresRequest { gpus_per_node: 1 }))?;
    let rank0 = &ranks[0];
    println!(
        "\n== srun --gres=gpu:1 (job {}): rank 0 on node {}, CUDA_VISIBLE_DEVICES={} ==",
        alloc.job_id,
        rank0.node,
        rank0.env.get("CUDA_VISIBLE_DEVICES").unwrap()
    );

    // ---- Shifter: container with GPU support ------------------------------
    let mut opts = RunOptions::new(
        "tensorflow/tensorflow:1.0.0-devel-gpu-py3",
        &["python3", "mnist_train.py"],
    );
    opts.env = rank0.env.clone();
    opts.node = rank0.node as usize;
    let container = site.run(&opts)?;
    let gpus = container.visible_gpus(&daint, rank0.node as usize);
    println!(
        "container up in {:.1} ms; GPU support: {:?} -> {}",
        container.startup_overhead_secs() * 1e3,
        container.gpu.as_ref().map(|g| &g.host_devices),
        gpus[0].name
    );

    // ---- the real compute: containerized training via PJRT ---------------
    println!("\n== containerized training: {steps} real steps of mnist_train ==");
    let executor = Executor::new(shifter_rs::runtime::default_artifact_dir())?;
    println!("PJRT platform: {}", executor.platform());
    let container_run =
        tf_trainer::run_real_training(&executor, TfWorkload::Mnist, steps, 42)?;
    for (i, loss) in container_run.losses.iter().enumerate() {
        if i % (steps as usize / 15).max(1) == 0 || i + 1 == steps as usize {
            println!("  step {i:>5}  loss {loss:.4}");
        }
    }
    println!(
        "loss {:.4} -> {:.4} ({}), wall {:.1}s, {:.2} GF/s on CPU substrate",
        container_run.first_loss(),
        container_run.last_loss(),
        if container_run.loss_decreased() { "decreasing ✓" } else { "NOT decreasing ✗" },
        container_run.wall_secs,
        container_run.cpu_gflops
    );

    // ---- native run of the same artifact: identical bits ------------------
    println!("\n== native run (no container), same artifact, same seed ==");
    let native_run =
        tf_trainer::run_real_training(&executor, TfWorkload::Mnist, steps, 42)?;
    let identical = container_run
        .losses
        .iter()
        .zip(&native_run.losses)
        .all(|(a, b)| a == b);
    println!(
        "native loss {:.4} -> {:.4}; curves bit-identical: {}",
        native_run.first_loss(),
        native_run.last_loss(),
        if identical { "YES ✓ (same compiled bits)" } else { "no ✗" }
    );

    // ---- Table I projection ------------------------------------------------
    println!("\n== Table I projection for the full 9375-step MNIST run ==");
    for board in [
        GpuModel::quadro_k110m(),
        GpuModel::tesla_k40m(),
        GpuModel::tesla_p100(),
    ] {
        println!(
            "  {:<14} {:>8.0} s (paper: {})",
            board.name,
            tf_trainer::train_time_secs(TfWorkload::Mnist, &board),
            match board.name {
                "Quadro K110M" => 613,
                "Tesla K40m" => 105,
                _ => 36,
            }
        );
    }
    if !container_run.loss_decreased() || !identical {
        return Err("e2e validation failed".into());
    }
    println!("\ne2e OK");
    Ok(())
}
