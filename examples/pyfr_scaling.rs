//! PyFR multi-GPU scaling (the paper's §V.B.2 scenario): the same
//! container image deployed across the Linux Cluster and Piz Daint —
//! each declared as a `Site` — with GPU + MPI support, scaling from 1 to
//! 8 GPUs, plus a real flux-reconstruction integration through the
//! `pyfr_step` artifact.
//!
//! Run: `make artifacts && cargo run --release --example pyfr_scaling`

use shifter_rs::apps::pyfr::{self, PyfrRun};
use shifter_rs::runtime::Executor;
use shifter_rs::shifter::RunOptions;
use shifter_rs::wlm::{GresRequest, Slurm};
use shifter_rs::{Site, SystemProfile};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    println!("T106D turbine blade: {} cells, {} iterations, dt = {:.4e}\n",
        pyfr::T106D_CELLS, pyfr::T106D_ITERS, pyfr::T106D_DT);

    for (profile, configs) in [
        (SystemProfile::linux_cluster(), vec![1usize, 2, 4]),
        (SystemProfile::piz_daint(), vec![1, 2, 4, 8]),
    ] {
        println!("== {} ==", profile.name);
        let mut site = Site::builder()
            .profile(profile.clone())
            .nodes(8)
            .gateway_shards(1)
            .build()?;
        site.pull("pyfr-image:1.5.0")?;
        let mut slurm = Slurm::new(&profile);

        for gpus in configs {
            // allocate: one rank per GPU; cluster packs 2 GPUs/node at 4
            let nodes = match (profile.name, gpus) {
                ("Linux Cluster", 1) => 1,
                ("Linux Cluster", _) => 2,
                (_, g) => g as u32,
            };
            let gpn = (gpus as u32).div_ceil(nodes);
            let alloc = slurm.salloc(nodes)?;
            let ranks = slurm.srun(
                &alloc,
                gpus as u32,
                Some(GresRequest { gpus_per_node: gpn }),
            )?;

            // each rank starts the same container with GPU + MPI support
            let mut opts =
                RunOptions::new("pyfr-image:1.5.0", &["pyfr", "run", "-b", "cuda"])
                    .with_mpi();
            opts.env = ranks[0].env.clone();
            opts.concurrent_nodes = nodes;
            let container = site.run(&opts)?;
            let mpi = container
                .effective_mpi(&profile)
                .expect("pyfr image has MPI");

            let run = match profile.name {
                "Linux Cluster" => PyfrRun::cluster(gpus),
                _ => PyfrRun::daint(gpus),
            };
            let secs = pyfr::wallclock_secs(&run, &profile, &mpi);
            println!(
                "  {gpus} GPU{}  ranks={:<2}  mpi={:<14}  wall {:>7.0} s  (startup {:>5.1} ms)",
                if gpus > 1 { "s" } else { " " },
                ranks.len(),
                mpi.version_string(),
                secs,
                container.startup_overhead_secs() * 1e3,
            );
        }
        println!();
    }

    // real integration on the artifact partition
    println!("== real flux-reconstruction partition (pyfr_step artifact) ==");
    let executor = Executor::new(shifter_rs::runtime::default_artifact_dir())?;
    let report = pyfr::run_real_partition(&executor, 50)?;
    println!(
        "{} elements x {} iters: residual {:.4e} -> {:.4e}, wall {:.2}s",
        report.elements,
        report.iters,
        report.residuals.first().unwrap(),
        report.residuals.last().unwrap(),
        report.wall_secs
    );
    let finite = report.residuals.iter().all(|r| r.is_finite());
    println!("residuals finite: {}", if finite { "YES ✓" } else { "no ✗" });
    Ok(())
}
