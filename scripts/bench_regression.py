#!/usr/bin/env python3
"""Bench regression gate: compare fresh BENCH_* artifacts to baselines.

The simulation metrics in the bench artifacts are deterministic per
(seed, knobs): identical inputs produce identical timings, so any drift
is a real behavioral change. This script compares an allowlist of
hot-path metrics in freshly produced artifacts (``rust/BENCH_launch.json``,
``rust/BENCH_extensions.json``, ``rust/BENCH_distrib.json``) against
checked-in baselines under ``rust/bench_baselines/`` and fails when a
metric regressed (grew) past the tolerance (default 15%). Improvements
and sub-tolerance jitter pass, with a note.

Baselines must be produced with the same knobs CI uses (see
.github/workflows/ci.yml bench-smoke: LAUNCH_SCALE_NODES=256,
EXTENSION_OVERHEAD_NODES=64, GATEWAY_SCALE_NODES=500,
FEDERATION_SITES=3, FEDERATION_JOBS=32); artifacts whose
``max_nodes`` differs from the baseline are skipped with a notice
instead of mis-compared.

Usage:
    python3 scripts/bench_regression.py [--tolerance 0.15] \
        [--baseline-dir rust/bench_baselines] [--update] ARTIFACT...

``--update`` records the current artifacts as the new baselines (run it
locally with the CI env knobs, then commit the result). A missing
baseline is a bootstrap, not a failure: the gate passes with a notice
asking for ``--update``.

A baseline with ``"provisional": true`` (written by
``scripts/derive_baselines.py --provisional`` on machines without a
Rust toolchain) carries metric *keys* but no magnitudes: the gate
enforces that every expected metric is present, finite, and
non-negative — a renamed or vanished metric still fails — and prints a
promotion notice until a full-magnitude baseline is recorded.

``--promote-provisional`` closes that bootstrap from CI itself: when
the checked-in baseline is provisional and the fresh artifact passes
the schema check at the CI knobs, the fresh artifact replaces the
baseline in place. Once a file is promoted the provisional path no
longer applies to it — every later run takes the full magnitude
comparison. (CI uploads the promoted directory as an artifact; a
maintainer commits it, exactly like a local ``--update``.)
"""

import argparse
import json
import os
import shutil
import sys


def fmt(v):
    return f"{v:.6g}"


def launch_metrics(doc):
    """(config key, metric name) -> value for BENCH_launch.json."""
    out = {}
    for cfg in doc.get("configs", []):
        key = "{}/{}/{}".format(
            cfg.get("partitions"), int(cfg.get("nodes", 0)), cfg.get("phase")
        )
        report = cfg.get("report", {})
        total = report.get("total", {})
        for metric in ("p50_secs", "p95_secs", "p99_secs", "worst_secs"):
            if metric in total:
                out[f"{key}.total.{metric}"] = total[metric]
        pull = report.get("pull", {})
        for metric in ("queue_wait_secs", "turnaround_secs"):
            if metric in pull:
                out[f"{key}.pull.{metric}"] = pull[metric]
    return out


def extensions_metrics(doc):
    """(row key, metric name) -> value for BENCH_extensions.json."""
    out = {}
    for row in doc.get("inject_cost", []):
        key = "inject/{}/{}".format(
            row.get("extension"), int(row.get("nodes", 0))
        )
        out[f"{key}.inject_secs"] = row.get("inject_secs", 0.0)
    for row in doc.get("osu_net_split", []):
        key = "osu/{}B".format(int(row.get("size_bytes", 0)))
        out[f"{key}.host_fabric_us"] = row.get("host_fabric_us", 0.0)
        out[f"{key}.tcp_fallback_us"] = row.get("tcp_fallback_us", 0.0)
    return out


def distrib_metrics(doc):
    """(row key, metric name) -> value for BENCH_distrib.json."""
    out = {}
    for row in doc.get("fill", []):
        key = "fill/{}".format(int(row.get("nodes", 0)))
        out[f"{key}.broadcast_makespan_secs"] = row.get(
            "broadcast_makespan_secs", 0.0)
        out[f"{key}.cascade_makespan_secs"] = row.get(
            "cascade_makespan_secs", 0.0)
    lazy = doc.get("lazy", {})
    for metric in ("eager_p99_secs", "start_ready_p99_secs",
                   "tail_p99_secs"):
        if metric in lazy:
            out[f"lazy.{metric}"] = lazy[metric]
    chunks = doc.get("chunks", {})
    for metric in ("v1_turnaround_secs", "v2_turnaround_secs"):
        if metric in chunks:
            out[f"chunks.{metric}"] = chunks[metric]
    return out


def federation_metrics(doc):
    """(config key, metric name) -> value for BENCH_federation.json."""
    out = {}
    for cfg in ("pinned", "burst", "locality", "random"):
        report = doc.get(cfg, {})
        for metric in ("overflows", "replications", "replication_bytes",
                       "wan_transfer_secs", "makespan_secs"):
            if metric in report:
                out[f"{cfg}.{metric}"] = report[metric]
        wait = report.get("total_wait") or {}
        for metric in ("p50", "p99", "worst"):
            if metric in wait:
                out[f"{cfg}.total_wait.{metric}"] = wait[metric]
    return out


EXTRACTORS = {
    "launch_scale": launch_metrics,
    "extension_overhead": extensions_metrics,
    "distrib_cascade": distrib_metrics,
    "federation_burst": federation_metrics,
}


def compare_provisional(name, fresh, base):
    """Schema check against a magnitude-free provisional baseline."""
    extractor = EXTRACTORS.get(fresh.get("bench"))
    if extractor is None:
        print(f"  {name}: no allowlist for bench "
              f"'{fresh.get('bench')}', skipping")
        return []
    if fresh.get("max_nodes") != base.get("max_nodes"):
        print(f"  {name}: knob mismatch (max_nodes {fresh.get('max_nodes')} "
              f"vs baseline {base.get('max_nodes')}), skipping — regenerate "
              f"the baseline with the CI knobs")
        return []
    fresh_m = extractor(fresh)
    failures = []
    for key in base.get("expected_metrics", []):
        if key not in fresh_m:
            failures.append(
                f"{name}: expected metric {key} missing from the fresh "
                f"artifact"
            )
            continue
        value = fresh_m[key]
        finite = isinstance(value, (int, float)) and value == value \
            and value not in (float("inf"), float("-inf"))
        if not finite or value < 0.0:
            failures.append(f"{name}: {key} has invalid value {value!r}")
    n = len(base.get("expected_metrics", []))
    print(f"  {name}: provisional baseline — {n} metric keys verified "
          f"(schema only; promote to magnitudes with --update)")
    return failures


def compare(name, fresh, base, tolerance):
    """Return a list of failure strings for one artifact pair."""
    extractor = EXTRACTORS.get(fresh.get("bench"))
    if extractor is None:
        print(f"  {name}: no allowlist for bench "
              f"'{fresh.get('bench')}', skipping")
        return []
    if fresh.get("max_nodes") != base.get("max_nodes"):
        print(f"  {name}: knob mismatch (max_nodes {fresh.get('max_nodes')} "
              f"vs baseline {base.get('max_nodes')}), skipping — regenerate "
              f"the baseline with the CI knobs")
        return []

    fresh_m, base_m = extractor(fresh), extractor(base)
    failures = []
    regressions = improvements = stable = 0
    for key, expected in sorted(base_m.items()):
        if key not in fresh_m:
            failures.append(f"{name}: metric {key} disappeared")
            continue
        actual = fresh_m[key]
        if expected <= 0.0:
            # a zero-cost baseline only regresses by becoming nonzero
            if actual > 0.0:
                failures.append(
                    f"{name}: {key} was free, now {fmt(actual)}"
                )
            continue
        rel = (actual - expected) / expected
        if rel > tolerance:
            regressions += 1
            failures.append(
                f"{name}: {key} regressed {rel * 100.0:+.1f}% "
                f"({fmt(expected)} -> {fmt(actual)}, "
                f"tolerance {tolerance * 100.0:.0f}%)"
            )
        elif rel < -tolerance:
            improvements += 1
        else:
            stable += 1
    print(f"  {name}: {len(base_m)} metrics — {stable} stable, "
          f"{improvements} improved, {regressions} regressed")
    return failures


def main():
    ap = argparse.ArgumentParser(
        description="compare BENCH_* artifacts against checked-in baselines"
    )
    ap.add_argument("artifacts", nargs="+",
                    help="fresh artifact paths (e.g. rust/BENCH_launch.json)")
    ap.add_argument("--baseline-dir", default="rust/bench_baselines")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative growth allowed before failing (0.15 = 15%%)")
    ap.add_argument("--update", action="store_true",
                    help="record the current artifacts as the new baselines")
    ap.add_argument("--promote-provisional", action="store_true",
                    help="replace a provisional baseline with the fresh "
                         "artifact when it passes the schema check — the "
                         "file leaves provisional handling for good")
    args = ap.parse_args()

    failures = []
    bootstrap = []
    for artifact in args.artifacts:
        name = os.path.basename(artifact)
        baseline = os.path.join(args.baseline_dir, name)
        if not os.path.exists(artifact):
            failures.append(f"{name}: fresh artifact {artifact} not found "
                            f"(did the bench run?)")
            continue
        if args.update:
            os.makedirs(args.baseline_dir, exist_ok=True)
            shutil.copyfile(artifact, baseline)
            print(f"  {name}: baseline updated -> {baseline}")
            continue
        if not os.path.exists(baseline):
            bootstrap.append(name)
            continue
        with open(artifact) as f:
            fresh = json.load(f)
        with open(baseline) as f:
            base = json.load(f)
        if base.get("provisional"):
            schema_failures = compare_provisional(name, fresh, base)
            failures.extend(schema_failures)
            if args.promote_provisional and not schema_failures \
                    and fresh.get("max_nodes") == base.get("max_nodes"):
                shutil.copyfile(artifact, baseline)
                print(f"  {name}: provisional baseline PROMOTED to full "
                      f"magnitudes -> {baseline} (commit it)")
        else:
            failures.extend(compare(name, fresh, base, args.tolerance))

    if bootstrap:
        print(f"bootstrap: no baseline yet for {', '.join(bootstrap)} — "
              f"run scripts/bench_regression.py --update with the CI env "
              f"knobs and commit {args.baseline_dir}/")
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("bench regression gate passed")


if __name__ == "__main__":
    main()
