#!/usr/bin/env python3
"""Produce the first checked-in baselines for the bench regression gate.

Two modes:

* **Toolchain mode** (default when ``cargo`` is on PATH): run the
  gated benches with the exact CI bench-smoke knobs
  (``LAUNCH_SCALE_NODES=256``, ``EXTENSION_OVERHEAD_NODES=64``,
  ``GATEWAY_SCALE_NODES=500``, ``FEDERATION_SITES=3``,
  ``FEDERATION_JOBS=32``), then record the fresh artifacts via
  ``bench_regression.py --update``. The result is a full-magnitude
  baseline — commit ``rust/bench_baselines/``.

* **Provisional mode** (``--provisional``, or automatic when cargo is
  unavailable): write *schema* baselines that list every metric key the
  CI-knob runs must produce (derived from the bench config grids), with
  ``"provisional": true`` and no magnitudes. The gate then enforces
  metric presence/positivity — a renamed or vanished metric fails CI —
  but cannot flag magnitude drift until someone promotes the baseline.

Promotion paths (close the bootstrap for good):

* ``--update``: run the benches locally with the CI knobs and record
  the magnitudes. Hard error when cargo is missing — a promotion must
  never silently degrade back to a schema baseline.
* ``--from-artifacts A.json [B.json ...]``: promote BENCH_* artifacts
  that already exist (e.g. downloaded from the CI ``bench-smoke``
  artifact), after validating that they carry the CI knobs and every
  metric key the provisional schema expects. Needs no toolchain.

Either way the gate leaves bootstrap mode: a baseline file exists and
is compared on every PR.

Usage:
    python3 scripts/derive_baselines.py [--provisional | --update |
        --from-artifacts ARTIFACT...] [--baseline-dir rust/bench_baselines]
"""

import argparse
import json
import os
import shutil
import subprocess
import sys

# the CI bench-smoke knobs (.github/workflows/ci.yml) — baselines are
# only comparable when produced at exactly these caps
LAUNCH_SCALE_NODES = 256
EXTENSION_OVERHEAD_NODES = 64
GATEWAY_SCALE_NODES = 500
FEDERATION_SITES = 3
FEDERATION_JOBS = 32
# federation_burst reports max_nodes = sites * 48 (NODES_PER_SITE)
FEDERATION_MAX_NODES = FEDERATION_SITES * 48

# OSU message sizes priced by the net-split table
# (rust/src/fabric/mod.rs OSU_SIZES)
OSU_SIZES = [32, 128, 512, 2048, 8192, 32768, 131072, 524288, 2097152]


def launch_expected_metrics(cap):
    """Metric keys launch_scale emits at LAUNCH_SCALE_NODES=cap.

    Mirrors the bench's config grid: widths 1/64/1024/4096 clipped to
    the cap (the cap itself appended when not already the last width),
    homogeneous and heterogeneous partitions (hetero needs >= 2 nodes),
    cold and warm cache phases.
    """
    widths = [n for n in (1, 64, 1024, 4096) if n <= cap]
    if not widths or widths[-1] < cap:
        widths.append(cap)
    keys = []
    for partitions in ("homog", "hetero"):
        for nodes in widths:
            if partitions == "hetero" and nodes < 2:
                continue
            for phase in ("cold", "warm"):
                base = f"{partitions}/{nodes}/{phase}"
                for m in ("p50_secs", "p95_secs", "p99_secs",
                          "worst_secs"):
                    keys.append(f"{base}.total.{m}")
                for m in ("queue_wait_secs", "turnaround_secs"):
                    keys.append(f"{base}.pull.{m}")
    return keys


def extensions_expected_metrics(cap):
    """Metric keys extension_overhead emits at the CI cap."""
    widths = [w for w in (1, 64, 1024) if w <= max(cap, 1)]
    keys = []
    for ext in ("gpu", "mpi", "net"):
        for nodes in widths:
            keys.append(f"inject/{ext}/{nodes}.inject_secs")
    for size in OSU_SIZES:
        keys.append(f"osu/{size}B.host_fabric_us")
        keys.append(f"osu/{size}B.tcp_fallback_us")
    return keys


def distrib_expected_metrics(cap):
    """Metric keys gateway_scale's distrib artifact emits at the CI cap.

    Mirrors the bench's ``fill_widths()``: ~1/16 and ~1/4 of the cap,
    floored at 32 nodes, then the cap itself (deduplicated).
    """
    def clamp(w):
        return min(max(w, min(32, cap)), cap)

    widths = []
    for w in (clamp(-(-cap // 16)), clamp(-(-cap // 4)), cap):
        if w not in widths:
            widths.append(w)
    keys = []
    for w in widths:
        keys.append(f"fill/{w}.broadcast_makespan_secs")
        keys.append(f"fill/{w}.cascade_makespan_secs")
    keys += [f"lazy.{m}" for m in ("eager_p99_secs",
                                   "start_ready_p99_secs",
                                   "tail_p99_secs")]
    keys += [f"chunks.{m}" for m in ("v1_turnaround_secs",
                                     "v2_turnaround_secs")]
    return keys


def federation_expected_metrics(_cap):
    """Metric keys federation_burst emits (any site/job knobs)."""
    keys = []
    for cfg in ("pinned", "burst", "locality", "random"):
        for m in ("overflows", "replications", "replication_bytes",
                  "wan_transfer_secs", "makespan_secs"):
            keys.append(f"{cfg}.{m}")
        for m in ("p50", "p99", "worst"):
            keys.append(f"{cfg}.total_wait.{m}")
    return keys


PROVISIONAL = [
    ("BENCH_launch.json", "launch_scale", LAUNCH_SCALE_NODES,
     launch_expected_metrics),
    ("BENCH_extensions.json", "extension_overhead",
     EXTENSION_OVERHEAD_NODES, extensions_expected_metrics),
    ("BENCH_distrib.json", "distrib_cascade", GATEWAY_SCALE_NODES,
     distrib_expected_metrics),
    ("BENCH_federation.json", "federation_burst", FEDERATION_MAX_NODES,
     federation_expected_metrics),
]


def write_provisional(baseline_dir):
    os.makedirs(baseline_dir, exist_ok=True)
    for name, bench, cap, expected in PROVISIONAL:
        doc = {
            "bench": bench,
            "max_nodes": cap,
            "provisional": True,
            "note": ("schema baseline: metric keys only; promote to "
                     "magnitudes with scripts/derive_baselines.py on a "
                     "machine with a Rust toolchain"),
            "expected_metrics": expected(cap),
        }
        path = os.path.join(baseline_dir, name)
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"  {name}: provisional baseline "
              f"({len(doc['expected_metrics'])} metric keys) -> {path}")


def run_benches_and_update(baseline_dir):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    benches = [
        ("launch_scale", {"LAUNCH_SCALE_NODES": str(LAUNCH_SCALE_NODES)}),
        ("extension_overhead",
         {"EXTENSION_OVERHEAD_NODES": str(EXTENSION_OVERHEAD_NODES)}),
        ("gateway_scale",
         {"GATEWAY_SCALE_NODES": str(GATEWAY_SCALE_NODES)}),
        ("federation_burst",
         {"FEDERATION_SITES": str(FEDERATION_SITES),
          "FEDERATION_JOBS": str(FEDERATION_JOBS)}),
    ]
    for bench, knobs in benches:
        print(f"  running cargo bench --bench {bench} ({knobs})")
        subprocess.run(
            ["cargo", "bench", "--bench", bench],
            cwd=os.path.join(root, "rust"),
            env={**env, **knobs},
            check=True,
        )
    subprocess.run(
        [sys.executable, os.path.join(root, "scripts",
                                      "bench_regression.py"),
         "--update", "--baseline-dir", baseline_dir,
         os.path.join(root, "rust", "BENCH_launch.json"),
         os.path.join(root, "rust", "BENCH_extensions.json"),
         os.path.join(root, "rust", "BENCH_distrib.json"),
         os.path.join(root, "rust", "BENCH_federation.json")],
        check=True,
    )


def promote_from_artifacts(baseline_dir, artifacts):
    """Promote existing BENCH_* artifacts to full-magnitude baselines.

    Validates each artifact against the provisional schema (CI knobs +
    every expected metric key present, finite, non-negative) before
    copying it over the baseline, so a truncated or wrong-knob artifact
    can never replace the schema gate.
    """
    schema = {name: (bench, cap, expected(cap))
              for name, bench, cap, expected in PROVISIONAL}
    errors = []
    for artifact in artifacts:
        name = os.path.basename(artifact)
        if name not in schema:
            errors.append(f"{name}: not a promotable baseline "
                          f"(expected one of {sorted(schema)})")
            continue
        bench, cap, expected = schema[name]
        with open(artifact) as f:
            doc = json.load(f)
        if doc.get("bench") != bench:
            errors.append(f"{name}: bench '{doc.get('bench')}' != '{bench}'")
            continue
        if doc.get("max_nodes") != cap:
            errors.append(f"{name}: max_nodes {doc.get('max_nodes')} != "
                          f"CI knob {cap} — rerun with the CI env knobs")
            continue
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        import bench_regression
        metrics = bench_regression.EXTRACTORS[bench](doc)
        missing = [k for k in expected if k not in metrics]
        bad = [k for k, v in metrics.items()
               if not isinstance(v, (int, float)) or v != v or v < 0.0]
        if missing or bad:
            for k in missing:
                errors.append(f"{name}: expected metric {k} missing")
            for k in bad:
                errors.append(f"{name}: metric {k} has invalid value")
            continue
        os.makedirs(baseline_dir, exist_ok=True)
        shutil.copyfile(artifact, os.path.join(baseline_dir, name))
        print(f"  {name}: promoted to full-magnitude baseline "
              f"({len(metrics)} metrics) -> {baseline_dir}/{name}")
    if errors:
        print("promotion FAILED:")
        for e in errors:
            print(f"  {e}")
        sys.exit(1)


def main():
    ap = argparse.ArgumentParser(
        description="derive first baselines for the bench regression gate"
    )
    ap.add_argument("--baseline-dir", default="rust/bench_baselines")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--provisional", action="store_true",
                      help="write schema-only baselines without running "
                           "the benches (automatic when cargo is missing)")
    mode.add_argument("--update", action="store_true",
                      help="run the benches with the CI knobs and record "
                           "full-magnitude baselines (requires cargo)")
    mode.add_argument("--from-artifacts", nargs="+", metavar="ARTIFACT",
                      help="promote existing BENCH_* artifacts (e.g. the "
                           "CI bench-smoke upload) to full-magnitude "
                           "baselines; no toolchain needed")
    args = ap.parse_args()

    if args.from_artifacts:
        promote_from_artifacts(args.baseline_dir, args.from_artifacts)
        return
    if args.update:
        if shutil.which("cargo") is None:
            print("error: --update needs a Rust toolchain (cargo not "
                  "found); either run on a machine with cargo, or promote "
                  "CI artifacts with --from-artifacts", file=sys.stderr)
            sys.exit(2)
        run_benches_and_update(args.baseline_dir)
        return
    if args.provisional or shutil.which("cargo") is None:
        if not args.provisional:
            print("cargo not found — falling back to provisional "
                  "schema baselines")
        write_provisional(args.baseline_dir)
        return
    run_benches_and_update(args.baseline_dir)


if __name__ == "__main__":
    main()
