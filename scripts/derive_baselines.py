#!/usr/bin/env python3
"""Produce the first checked-in baselines for the bench regression gate.

Two modes:

* **Toolchain mode** (default when ``cargo`` is on PATH): run the
  gated benches with the exact CI bench-smoke knobs
  (``LAUNCH_SCALE_NODES=256``, ``EXTENSION_OVERHEAD_NODES=64``,
  ``GATEWAY_SCALE_NODES=500``), then record the fresh artifacts via
  ``bench_regression.py --update``. The result is a full-magnitude
  baseline — commit ``rust/bench_baselines/``.

* **Provisional mode** (``--provisional``, or automatic when cargo is
  unavailable): write *schema* baselines that list every metric key the
  CI-knob runs must produce (derived from the bench config grids), with
  ``"provisional": true`` and no magnitudes. The gate then enforces
  metric presence/positivity — a renamed or vanished metric fails CI —
  but cannot flag magnitude drift until someone promotes the baseline
  by re-running this script (or ``bench_regression.py --update``) with
  a real toolchain.

Either way the gate leaves bootstrap mode: a baseline file exists and
is compared on every PR.

Usage:
    python3 scripts/derive_baselines.py [--provisional] \
        [--baseline-dir rust/bench_baselines]
"""

import argparse
import json
import os
import shutil
import subprocess
import sys

# the CI bench-smoke knobs (.github/workflows/ci.yml) — baselines are
# only comparable when produced at exactly these caps
LAUNCH_SCALE_NODES = 256
EXTENSION_OVERHEAD_NODES = 64
GATEWAY_SCALE_NODES = 500

# OSU message sizes priced by the net-split table
# (rust/src/fabric/mod.rs OSU_SIZES)
OSU_SIZES = [32, 128, 512, 2048, 8192, 32768, 131072, 524288, 2097152]


def launch_expected_metrics(cap):
    """Metric keys launch_scale emits at LAUNCH_SCALE_NODES=cap.

    Mirrors the bench's config grid: widths 1/64/1024/4096 clipped to
    the cap (the cap itself appended when not already the last width),
    homogeneous and heterogeneous partitions (hetero needs >= 2 nodes),
    cold and warm cache phases.
    """
    widths = [n for n in (1, 64, 1024, 4096) if n <= cap]
    if not widths or widths[-1] < cap:
        widths.append(cap)
    keys = []
    for partitions in ("homog", "hetero"):
        for nodes in widths:
            if partitions == "hetero" and nodes < 2:
                continue
            for phase in ("cold", "warm"):
                base = f"{partitions}/{nodes}/{phase}"
                for m in ("p50_secs", "p95_secs", "p99_secs",
                          "worst_secs"):
                    keys.append(f"{base}.total.{m}")
                for m in ("queue_wait_secs", "turnaround_secs"):
                    keys.append(f"{base}.pull.{m}")
    return keys


def extensions_expected_metrics(cap):
    """Metric keys extension_overhead emits at the CI cap."""
    widths = [w for w in (1, 64, 1024) if w <= max(cap, 1)]
    keys = []
    for ext in ("gpu", "mpi", "net"):
        for nodes in widths:
            keys.append(f"inject/{ext}/{nodes}.inject_secs")
    for size in OSU_SIZES:
        keys.append(f"osu/{size}B.host_fabric_us")
        keys.append(f"osu/{size}B.tcp_fallback_us")
    return keys


def distrib_expected_metrics(cap):
    """Metric keys gateway_scale's distrib artifact emits at the CI cap.

    Mirrors the bench's ``fill_widths()``: ~1/16 and ~1/4 of the cap,
    floored at 32 nodes, then the cap itself (deduplicated).
    """
    def clamp(w):
        return min(max(w, min(32, cap)), cap)

    widths = []
    for w in (clamp(-(-cap // 16)), clamp(-(-cap // 4)), cap):
        if w not in widths:
            widths.append(w)
    keys = []
    for w in widths:
        keys.append(f"fill/{w}.broadcast_makespan_secs")
        keys.append(f"fill/{w}.cascade_makespan_secs")
    keys += [f"lazy.{m}" for m in ("eager_p99_secs",
                                   "start_ready_p99_secs",
                                   "tail_p99_secs")]
    keys += [f"chunks.{m}" for m in ("v1_turnaround_secs",
                                     "v2_turnaround_secs")]
    return keys


PROVISIONAL = [
    ("BENCH_launch.json", "launch_scale", LAUNCH_SCALE_NODES,
     launch_expected_metrics),
    ("BENCH_extensions.json", "extension_overhead",
     EXTENSION_OVERHEAD_NODES, extensions_expected_metrics),
    ("BENCH_distrib.json", "distrib_cascade", GATEWAY_SCALE_NODES,
     distrib_expected_metrics),
]


def write_provisional(baseline_dir):
    os.makedirs(baseline_dir, exist_ok=True)
    for name, bench, cap, expected in PROVISIONAL:
        doc = {
            "bench": bench,
            "max_nodes": cap,
            "provisional": True,
            "note": ("schema baseline: metric keys only; promote to "
                     "magnitudes with scripts/derive_baselines.py on a "
                     "machine with a Rust toolchain"),
            "expected_metrics": expected(cap),
        }
        path = os.path.join(baseline_dir, name)
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=False)
            f.write("\n")
        print(f"  {name}: provisional baseline "
              f"({len(doc['expected_metrics'])} metric keys) -> {path}")


def run_benches_and_update(baseline_dir):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    benches = [
        ("launch_scale", {"LAUNCH_SCALE_NODES": str(LAUNCH_SCALE_NODES)}),
        ("extension_overhead",
         {"EXTENSION_OVERHEAD_NODES": str(EXTENSION_OVERHEAD_NODES)}),
        ("gateway_scale",
         {"GATEWAY_SCALE_NODES": str(GATEWAY_SCALE_NODES)}),
    ]
    for bench, knobs in benches:
        print(f"  running cargo bench --bench {bench} ({knobs})")
        subprocess.run(
            ["cargo", "bench", "--bench", bench],
            cwd=os.path.join(root, "rust"),
            env={**env, **knobs},
            check=True,
        )
    subprocess.run(
        [sys.executable, os.path.join(root, "scripts",
                                      "bench_regression.py"),
         "--update", "--baseline-dir", baseline_dir,
         os.path.join(root, "rust", "BENCH_launch.json"),
         os.path.join(root, "rust", "BENCH_extensions.json"),
         os.path.join(root, "rust", "BENCH_distrib.json")],
        check=True,
    )


def main():
    ap = argparse.ArgumentParser(
        description="derive first baselines for the bench regression gate"
    )
    ap.add_argument("--baseline-dir", default="rust/bench_baselines")
    ap.add_argument("--provisional", action="store_true",
                    help="write schema-only baselines without running "
                         "the benches (automatic when cargo is missing)")
    args = ap.parse_args()

    if args.provisional or shutil.which("cargo") is None:
        if not args.provisional:
            print("cargo not found — falling back to provisional "
                  "schema baselines")
        write_provisional(args.baseline_dir)
        return
    run_benches_and_update(args.baseline_dir)


if __name__ == "__main__":
    main()
