//! Fixture-driven coverage for every shifter-lint rule (ISSUE 9 satellite):
//! one positive and one negative fixture per rule, plus the baseline
//! round-trip (`--init`/`--update-baseline` semantics) over a temp tree.

use std::path::{Path, PathBuf};

use shifter_lint::baseline::Baseline;
use shifter_lint::diag::Diagnostic;
use shifter_lint::rules::{check, Config, RULE_IDS};

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn lint_fixture(name: &str) -> Vec<Diagnostic> {
    let path = fixture_dir().join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {name} unreadable: {e}"));
    check(name, &src, &Config::default_policy())
}

#[test]
fn every_rule_has_positive_and_negative_fixtures() {
    let cases = [
        ("wall-clock", "wall_clock"),
        ("unordered-collection", "unordered"),
        ("float-ordering", "float_ordering"),
        ("unwrap", "unwrap"),
        ("thread", "thread"),
        ("lock-poison", "lock_poison"),
        ("entropy-seed", "entropy_seed"),
    ];
    assert_eq!(cases.len(), RULE_IDS.len(), "a rule is missing fixture coverage");
    for (rule, stem) in cases {
        let pos = lint_fixture(&format!("{stem}_pos.rs"));
        assert!(
            pos.iter().any(|d| d.rule == rule && d.is_active()),
            "positive fixture for `{rule}` produced no active diagnostic: {pos:?}"
        );
        let neg = lint_fixture(&format!("{stem}_neg.rs"));
        let bad: Vec<&Diagnostic> =
            neg.iter().filter(|d| d.rule == rule && d.is_active()).collect();
        assert!(
            bad.is_empty(),
            "negative fixture for `{rule}` produced active diagnostics: {bad:?}"
        );
    }
}

#[test]
fn lock_poison_claims_its_unwrap_site() {
    let diags = lint_fixture("lock_poison_pos.rs");
    assert!(diags.iter().any(|d| d.rule == "lock-poison"));
    assert!(
        !diags.iter().any(|d| d.rule == "unwrap"),
        "a .lock().unwrap() site must be reported once, as lock-poison"
    );
}

#[test]
fn inline_allow_is_suppressed_but_recorded() {
    let diags = lint_fixture("unwrap_neg.rs");
    let justified: Vec<&Diagnostic> =
        diags.iter().filter(|d| d.rule == "unwrap").collect();
    assert_eq!(justified.len(), 1, "the lint:allow site should still be recorded");
    assert!(!justified[0].is_active());
}

/// Source with `n` unwrap sites, used to exercise the ratchet.
fn debt_module(n: usize) -> String {
    let mut s = String::from("pub fn drain(v: Vec<Option<u32>>) {\n");
    for i in 0..n {
        s.push_str(&format!("    let _x{i} = v[{i}].unwrap();\n"));
    }
    s.push_str("}\n");
    s
}

#[test]
fn baseline_round_trip_ratchets_down_never_up() {
    let dir = std::env::temp_dir().join(format!("shifter-lint-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let file = dir.join("mod_a.rs");
    let bl_path = dir.join("baseline.toml");
    let cfg = Config::default_policy();
    let key = ("unwrap".to_string(), "mod_a.rs".to_string());

    // Bootstrap: 3 sites of debt, --init-baseline, clean run.
    std::fs::write(&file, debt_module(3)).expect("write fixture");
    let diags = shifter_lint::lint_root(&dir, &cfg).expect("lint");
    let bl = Baseline::init(&Baseline::current_counts(&diags));
    bl.save(&bl_path).expect("save baseline");
    let loaded = Baseline::load(&bl_path).expect("reload baseline");
    assert_eq!(bl, loaded, "baseline must survive a save/load round trip");
    let res = shifter_lint::run(&dir, &cfg, &loaded).expect("run");
    assert_eq!(res.active, 0);
    assert_eq!(res.suppressed, 3);

    // Pay off one site; --update-baseline lowers the count to 2.
    std::fs::write(&file, debt_module(2)).expect("write fixture");
    let diags = shifter_lint::lint_root(&dir, &cfg).expect("lint");
    let mut bl = loaded;
    bl.ratchet(&Baseline::current_counts(&diags));
    bl.save(&bl_path).expect("save baseline");
    let bl = Baseline::load(&bl_path).expect("reload baseline");
    assert_eq!(bl.entries.get(&key), Some(&2));

    // Regress to 4 sites: the allowance stays at 2, two diagnostics live.
    std::fs::write(&file, debt_module(4)).expect("write fixture");
    let res = shifter_lint::run(&dir, &cfg, &bl).expect("run");
    assert_eq!(res.active, 2, "new debt must not be absorbed by the baseline");
    assert_eq!(res.suppressed, 2);

    let _ = std::fs::remove_dir_all(&dir);
}
