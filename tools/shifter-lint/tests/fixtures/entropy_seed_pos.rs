//! Positive fixture: ambient-entropy seed sources.
use std::collections::hash_map::DefaultHasher;
use std::collections::hash_map::RandomState;

pub fn roll() -> u64 {
    let h = DefaultHasher::new();
    let s = RandomState::new();
    let rng = rand::thread_rng();
    let _ = (h, s, rng);
    0
}
