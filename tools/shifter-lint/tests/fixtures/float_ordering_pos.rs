//! Positive fixture: NaN-unsafe float ordering.
pub fn rank(xs: &mut Vec<f64>) -> std::cmp::Ordering {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs.sort_unstable_by(|a, b| b.partial_cmp(a).expect("no NaN"));
    xs[0].partial_cmp(&xs[1]).unwrap()
}
