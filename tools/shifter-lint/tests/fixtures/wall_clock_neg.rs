//! Negative fixture: Instant::now() in comments, strings and tests is fine.
pub fn virtual_now(clock: &SimClock) -> SimTime {
    let banner = "SystemTime::now() belongs in strings only";
    let _ = banner;
    clock.now()
}

#[cfg(test)]
mod tests {
    #[test]
    fn wall_clock_is_fine_in_tests() {
        let _ = std::time::Instant::now();
    }
}
