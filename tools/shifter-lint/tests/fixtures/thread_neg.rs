//! Negative fixture: concurrency modeled on the SimKernel; real threads
//! only inside test items.
pub fn fan_out(kernel: &mut SimKernel) {
    kernel.schedule_in(0.5, Event::worker(1));
}

#[cfg(test)]
mod tests {
    #[test]
    fn scoped_threads_in_tests() {
        std::thread::scope(|s| {
            let _ = s;
        });
        let h = std::thread::spawn(|| ());
        let _ = h.join();
    }
}
