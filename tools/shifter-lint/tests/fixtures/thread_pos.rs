//! Positive fixture: host thread primitives in library code.
pub fn fan_out() -> u32 {
    let handle = std::thread::spawn(|| 42);
    std::thread::scope(|s| {
        let _ = s;
    });
    handle.join().unwrap_or(0)
}
