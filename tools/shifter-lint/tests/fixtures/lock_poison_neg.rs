//! Negative fixture: poison-tolerant locking (the util::sync pattern).
pub fn snapshot(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    let guard = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    guard.len()
}
