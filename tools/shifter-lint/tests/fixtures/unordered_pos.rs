//! Positive fixture: HashMap/HashSet in library code.
use std::collections::HashMap;
use std::collections::HashSet;

pub fn build_index(keys: &[String]) -> HashMap<String, u32> {
    let mut seen: HashSet<&str> = HashSet::new();
    let mut index = HashMap::new();
    for (i, k) in keys.iter().enumerate() {
        if seen.insert(k.as_str()) {
            index.insert(k.clone(), i as u32);
        }
    }
    index
}
