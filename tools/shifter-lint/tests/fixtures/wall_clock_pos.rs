//! Positive fixture: host wall-clock reads in library code.
use std::time::Instant;

pub fn elapsed_wall() -> f64 {
    let start = Instant::now();
    let epoch = std::time::SystemTime::now();
    let _ = (start, epoch);
    0.0
}
