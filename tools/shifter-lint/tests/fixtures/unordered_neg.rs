//! Negative fixture: ordered collections, plus HashMap mentions that are
//! only trivia. A HashMap in a comment or "HashMap in a string" is fine.
use std::collections::{BTreeMap, BTreeSet};

pub fn build_index(keys: &[String]) -> BTreeMap<String, u32> {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    let mut index = BTreeMap::new();
    for (i, k) in keys.iter().enumerate() {
        if seen.insert(k.as_str()) {
            index.insert(k.clone(), i as u32);
        }
    }
    index
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn tests_may_use_hash_maps() {
        let mut m = HashMap::new();
        m.insert(1, 2);
    }
}
