//! Negative fixture: total_cmp ordering, and a PartialOrd impl whose
//! partial_cmp definition (and non-unwrapped use) must not be flagged.
pub fn rank(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub struct Key(pub f64);

impl PartialEq for Key {
    fn eq(&self, other: &Key) -> bool {
        self.0 == other.0
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Key) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(&other.0)
    }
}
