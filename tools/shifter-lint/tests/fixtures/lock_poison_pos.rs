//! Positive fixture: unwrapping a poisoned mutex guard.
pub fn snapshot(m: &std::sync::Mutex<Vec<u32>>) -> usize {
    let guard = m.lock().unwrap();
    let tele = m.lock().expect("telemetry lock poisoned");
    guard.len() + tele.len()
}
