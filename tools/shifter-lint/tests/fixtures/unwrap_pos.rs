//! Positive fixture: bare unwrap/expect in non-test library code.
pub fn read_config(path: &str) -> String {
    let text = std::fs::read_to_string(path).unwrap();
    let first = text.lines().next().expect("config is non-empty");
    first.to_string()
}
