//! Negative fixture: propagation, defaulted unwrap variants, test code,
//! and a justified inline allow.
pub fn read_config(path: &str) -> Result<String, std::io::Error> {
    let text = std::fs::read_to_string(path)?;
    Ok(text)
}

pub fn fallback(v: Option<u32>) -> u32 {
    v.unwrap_or(0).max(v.unwrap_or_else(|| 1))
}

// lint:allow(unwrap): fixture demonstrating a justified one-off
pub fn justified(v: Option<u32>) -> u32 {
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(3).unwrap(), 3);
    }
}
