//! Negative fixture: explicit deterministic seeding.
pub fn seed_from(token: u64) -> SplitMix64 {
    SplitMix64::new(token ^ 0x9e37_79b9_7f4a_7c15)
}

#[cfg(test)]
mod tests {
    use std::collections::hash_map::RandomState;

    #[test]
    fn tests_may_use_ambient_entropy() {
        let _ = RandomState::new();
    }
}
