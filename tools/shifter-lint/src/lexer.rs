//! Minimal Rust token scanner for shifter-lint.
//!
//! This is deliberately not a full parser: the lint rules (DESIGN.md S26)
//! only need a comment/string-free token stream with positions, plus the
//! inline `lint:allow(...)` directives found in comments. The scanner
//! handles the lexical constructs that would otherwise produce false
//! positives — line and nested block comments, regular/raw/byte string
//! literals, char literals vs. lifetimes, and raw identifiers (`r#type`).
//!
//! Kept in lockstep with the rule engine in [`crate::rules`]; any change
//! here needs matching fixtures under `tests/fixtures/`.

/// Classification of a scanned token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (including raw identifiers, prefix stripped).
    Ident,
    /// Numeric literal (integers, floats, suffixed literals).
    Number,
    /// Any single punctuation character.
    Punct,
}

/// One scanned token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// An inline suppression directive: `// lint:allow(rule-a, rule-b): why`.
///
/// Suppresses matching diagnostics on the directive's own line and on the
/// line immediately following it (so a directive can sit on its own line
/// above the code it excuses).
#[derive(Debug, Clone)]
pub struct InlineAllow {
    /// Line the directive starts on.
    pub line: u32,
    /// Rule names listed inside the parentheses (`all` matches any rule).
    pub rules: Vec<String>,
}

/// Output of [`lex`]: the token stream plus inline allow directives.
#[derive(Debug, Default)]
pub struct LexOutput {
    pub tokens: Vec<Token>,
    pub allows: Vec<InlineAllow>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Cursor {
    chars: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn new(src: &str) -> Cursor {
        Cursor {
            chars: src.chars().collect(),
            i: 0,
            line: 1,
            col: 1,
        }
    }

    fn eof(&self) -> bool {
        self.i >= self.chars.len()
    }

    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.i + ahead).copied()
    }

    fn bump(&mut self) {
        if let Some(&c) = self.chars.get(self.i) {
            if c == '\n' {
                self.line += 1;
                self.col = 1;
            } else {
                self.col += 1;
            }
            self.i += 1;
        }
    }

    fn bump_n(&mut self, n: usize) {
        for _ in 0..n {
            self.bump();
        }
    }

    /// Collect chars from the current position while `pred` holds.
    fn take_while(&mut self, pred: fn(char) -> bool) -> String {
        let mut s = String::new();
        while let Some(c) = self.peek(0) {
            if !pred(c) {
                break;
            }
            s.push(c);
            self.bump();
        }
        s
    }
}

/// Extract `lint:allow(rule, ...)` directives from a comment's text.
fn parse_allow(comment: &str, line: u32, out: &mut Vec<InlineAllow>) {
    let Some(pos) = comment.find("lint:allow(") else {
        return;
    };
    let rest = &comment[pos + "lint:allow(".len()..];
    let Some(close) = rest.find(')') else {
        return;
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if !rules.is_empty() {
        out.push(InlineAllow { line, rules });
    }
}

/// Scan `src` into tokens, skipping trivia that could alias rule patterns.
pub fn lex(src: &str) -> LexOutput {
    let mut cur = Cursor::new(src);
    let mut out = LexOutput::default();

    while !cur.eof() {
        let c = match cur.peek(0) {
            Some(c) => c,
            None => break,
        };

        if c == ' ' || c == '\t' || c == '\r' || c == '\n' {
            cur.bump();
            continue;
        }

        // Line comment (also covers `///` and `//!` doc comments).
        if c == '/' && cur.peek(1) == Some('/') {
            let start_line = cur.line;
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            parse_allow(&text, start_line, &mut out.allows);
            continue;
        }

        // Block comment, possibly nested.
        if c == '/' && cur.peek(1) == Some('*') {
            let start_line = cur.line;
            let mut text = String::new();
            let mut depth = 0usize;
            while !cur.eof() {
                if cur.peek(0) == Some('/') && cur.peek(1) == Some('*') {
                    depth += 1;
                    text.push_str("/*");
                    cur.bump_n(2);
                } else if cur.peek(0) == Some('*') && cur.peek(1) == Some('/') {
                    depth = depth.saturating_sub(1);
                    text.push_str("*/");
                    cur.bump_n(2);
                    if depth == 0 {
                        break;
                    }
                } else {
                    if let Some(ch) = cur.peek(0) {
                        text.push(ch);
                    }
                    cur.bump();
                }
            }
            parse_allow(&text, start_line, &mut out.allows);
            continue;
        }

        // Raw strings r"..." / r#"..."#, byte-raw br"...", raw idents r#type.
        if c == 'r' || c == 'b' {
            // Offset of the char right after the r/br prefix, if this is one.
            let after_prefix = if c == 'r' {
                Some(1)
            } else if cur.peek(1) == Some('r') {
                Some(2)
            } else {
                None
            };
            if let Some(off) = after_prefix {
                let next = cur.peek(off);
                if next == Some('#') || next == Some('"') {
                    let mut hashes = 0usize;
                    while cur.peek(off + hashes) == Some('#') {
                        hashes += 1;
                    }
                    if cur.peek(off + hashes) == Some('"') {
                        // Raw string: consume through closing quote + hashes.
                        cur.bump_n(off + hashes + 1);
                        'scan: while !cur.eof() {
                            if cur.peek(0) == Some('"') {
                                let mut k = 0usize;
                                while k < hashes && cur.peek(1 + k) == Some('#') {
                                    k += 1;
                                }
                                if k == hashes {
                                    cur.bump_n(1 + hashes);
                                    break 'scan;
                                }
                            }
                            cur.bump();
                        }
                        continue;
                    }
                    if c == 'r' && hashes == 1 {
                        if let Some(first) = cur.peek(off + 1) {
                            if is_ident_start(first) {
                                // Raw identifier r#type: token text is the
                                // bare ident so rules see it normally.
                                let line = cur.line;
                                let col = cur.col;
                                cur.bump_n(off + 1);
                                let text = cur.take_while(is_ident_cont);
                                out.tokens.push(Token {
                                    kind: TokenKind::Ident,
                                    text,
                                    line,
                                    col,
                                });
                                continue;
                            }
                        }
                    }
                }
            }
        }

        // Byte string b"..."
        if c == 'b' && cur.peek(1) == Some('"') {
            cur.bump_n(2);
            while let Some(ch) = cur.peek(0) {
                if ch == '"' {
                    break;
                }
                if ch == '\\' {
                    cur.bump_n(2);
                } else {
                    cur.bump();
                }
            }
            cur.bump();
            continue;
        }

        // Byte char b'x'
        if c == 'b' && cur.peek(1) == Some('\'') {
            cur.bump_n(2);
            if cur.peek(0) == Some('\\') {
                cur.bump_n(2);
            } else {
                cur.bump();
            }
            cur.bump(); // closing quote
            continue;
        }

        // Regular string literal.
        if c == '"' {
            cur.bump();
            while let Some(ch) = cur.peek(0) {
                if ch == '"' {
                    break;
                }
                if ch == '\\' {
                    cur.bump_n(2);
                } else {
                    cur.bump();
                }
            }
            cur.bump();
            continue;
        }

        // Char literal vs. lifetime.
        if c == '\'' {
            if cur.peek(1) == Some('\\') {
                // Escaped char literal: skip to the closing quote.
                cur.bump_n(2);
                while let Some(ch) = cur.peek(0) {
                    if ch == '\'' {
                        break;
                    }
                    cur.bump();
                }
                cur.bump();
                continue;
            }
            if cur.peek(2) == Some('\'') {
                // Plain char literal 'x'.
                cur.bump_n(3);
                continue;
            }
            // Lifetime: quote + identifier.
            cur.bump();
            cur.take_while(is_ident_cont);
            continue;
        }

        if is_ident_start(c) {
            let line = cur.line;
            let col = cur.col;
            let text = cur.take_while(is_ident_cont);
            out.tokens.push(Token {
                kind: TokenKind::Ident,
                text,
                line,
                col,
            });
            continue;
        }

        if c.is_ascii_digit() {
            let line = cur.line;
            let col = cur.col;
            let mut text = cur.take_while(is_ident_cont);
            // Fractional part: only when a digit follows the dot, so method
            // calls on integers (`1.max(2)`) keep their `.` as punctuation.
            if cur.peek(0) == Some('.') {
                if let Some(d) = cur.peek(1) {
                    if d.is_ascii_digit() {
                        text.push('.');
                        cur.bump();
                        text.push_str(&cur.take_while(is_ident_cont));
                    }
                }
            }
            out.tokens.push(Token {
                kind: TokenKind::Number,
                text,
                line,
                col,
            });
            continue;
        }

        out.tokens.push(Token {
            kind: TokenKind::Punct,
            text: c.to_string(),
            line: cur.line,
            col: cur.col,
        });
        cur.bump();
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn skips_comments_and_strings() {
        let src = r##"
            // HashMap in a comment
            /* Instant::now() in /* a nested */ block */
            let s = "HashMap::new()";
            let r = r#"SystemTime::now()"#;
            let x = real_ident;
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"Instant".to_string()));
        assert!(!ids.contains(&"SystemTime".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { let c = 'x'; x }";
        let ids = idents(src);
        assert!(ids.contains(&"str".to_string()));
        // The lifetime name must not appear as an ident token.
        assert_eq!(ids.iter().filter(|s| s.as_str() == "a").count(), 0);
    }

    #[test]
    fn raw_identifiers_lex_as_bare_idents() {
        let ids = idents("let r#type = 1; let broadcast = r2d2;");
        assert!(ids.contains(&"type".to_string()));
        assert!(ids.contains(&"broadcast".to_string()));
        assert!(ids.contains(&"r2d2".to_string()));
    }

    #[test]
    fn inline_allow_directives_are_collected() {
        let src = "// lint:allow(unwrap, wall-clock): bench-only scaffolding\nlet x = 1;";
        let out = lex(src);
        assert_eq!(out.allows.len(), 1);
        assert_eq!(out.allows[0].line, 1);
        assert_eq!(out.allows[0].rules, vec!["unwrap", "wall-clock"]);
    }

    #[test]
    fn positions_are_one_based() {
        let out = lex("ab\n  cd");
        assert_eq!(out.tokens[0].line, 1);
        assert_eq!(out.tokens[0].col, 1);
        assert_eq!(out.tokens[1].line, 2);
        assert_eq!(out.tokens[1].col, 3);
    }
}
