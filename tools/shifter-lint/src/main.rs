//! CLI for shifter-lint (DESIGN.md S26).
//!
//! ```text
//! cargo run -p shifter-lint -- [--format human|json] [--root PATH]
//!                              [--baseline PATH] [--update-baseline]
//!                              [--init-baseline]
//! ```
//!
//! Exit codes: 0 clean, 1 live diagnostics, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use shifter_lint::baseline::Baseline;
use shifter_lint::diag;
use shifter_lint::rules::{Config, RULE_IDS};

const USAGE: &str = "\
shifter-lint: determinism/error-handling invariants for the shifter-rs tree

USAGE:
    shifter-lint [OPTIONS]

OPTIONS:
    --format <human|json>   Diagnostic output format (default: human)
    --root <PATH>           Tree to lint (default: <workspace>/rust/src)
    --baseline <PATH>       Suppression baseline (default: <crate>/baseline.toml)
    --update-baseline       Ratchet baseline counts DOWN to current debt
    --init-baseline         Bootstrap the baseline from the current tree
    -h, --help              Show this help
";

struct Opts {
    format: String,
    root: PathBuf,
    baseline: PathBuf,
    update_baseline: bool,
    init_baseline: bool,
}

fn parse_opts() -> Result<Opts, String> {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut opts = Opts {
        format: "human".to_string(),
        root: manifest.join("../../rust/src"),
        baseline: manifest.join("baseline.toml"),
        update_baseline: false,
        init_baseline: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--format" => {
                let v = args.next().ok_or("--format needs a value")?;
                if v != "human" && v != "json" {
                    return Err(format!("unknown format `{v}` (expected human|json)"));
                }
                opts.format = v;
            }
            "--root" => {
                opts.root = PathBuf::from(args.next().ok_or("--root needs a value")?);
            }
            "--baseline" => {
                opts.baseline = PathBuf::from(args.next().ok_or("--baseline needs a value")?);
            }
            "--update-baseline" => opts.update_baseline = true,
            "--init-baseline" => opts.init_baseline = true,
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_opts() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("shifter-lint: {e}\n\n{USAGE}");
            return ExitCode::from(2);
        }
    };

    let cfg = Config::default_policy();
    let mut baseline = match Baseline::load(&opts.baseline) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("shifter-lint: failed to load baseline: {e}");
            return ExitCode::from(2);
        }
    };

    let result = match shifter_lint::run(&opts.root, &cfg, &baseline) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("shifter-lint: failed to lint {}: {e}", opts.root.display());
            return ExitCode::from(2);
        }
    };

    if opts.init_baseline || opts.update_baseline {
        let current = Baseline::current_counts(&result.diagnostics);
        if opts.init_baseline {
            baseline = Baseline::init(&current);
            eprintln!(
                "shifter-lint: baseline initialized with {} entr{}",
                baseline.entries.len(),
                if baseline.entries.len() == 1 { "y" } else { "ies" }
            );
        } else {
            let changed = baseline.ratchet(&current);
            eprintln!(
                "shifter-lint: baseline ratcheted, {} entr{} lowered or dropped",
                changed,
                if changed == 1 { "y" } else { "ies" }
            );
        }
        if let Err(e) = baseline.save(&opts.baseline) {
            eprintln!("shifter-lint: failed to write baseline: {e}");
            return ExitCode::from(2);
        }
        return ExitCode::SUCCESS;
    }

    let root_str = opts.root.to_string_lossy().into_owned();
    if opts.format == "json" {
        print!("{}", diag::render_json(&root_str, &RULE_IDS, &result.diagnostics));
    } else {
        for d in result.diagnostics.iter().filter(|d| d.is_active()) {
            println!("{}", diag::render_human(d));
        }
        println!(
            "shifter-lint: {} file diagnostics, {} live, {} suppressed",
            result.diagnostics.len(),
            result.active,
            result.suppressed
        );
    }

    if result.active > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}
