//! Diagnostic type and renderers (rustc-style human output + JSON).
//!
//! JSON is emitted with a hand-rolled writer because the tool crate is
//! dependency-free (see Cargo.toml); output key order and diagnostic order
//! are deterministic so the CI artifact diffs cleanly between runs.

/// How (whether) a diagnostic has been suppressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suppressed {
    /// Live diagnostic: fails the lint run.
    No,
    /// Excused by an inline `lint:allow(...)` directive.
    Inline,
    /// Absorbed by the committed baseline (`baseline.toml`).
    Baseline,
}

/// One lint finding, anchored to a file/line/col in the scanned tree.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Rule identifier, e.g. `wall-clock`.
    pub rule: &'static str,
    /// Path relative to the lint root, `/`-separated.
    pub file: String,
    pub line: u32,
    pub col: u32,
    /// What was matched, e.g. `Instant::now`.
    pub message: String,
    /// The offending source line, for the rustc-style snippet.
    pub snippet: String,
    /// Rule-level remediation hint.
    pub help: &'static str,
    pub suppressed: Suppressed,
}

impl Diagnostic {
    pub fn is_active(&self) -> bool {
        self.suppressed == Suppressed::No
    }
}

/// Sort diagnostics into the canonical (file, line, col, rule) order.
pub fn sort_canonical(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.col, a.rule).cmp(&(b.file.as_str(), b.line, b.col, b.rule))
    });
}

/// Render one diagnostic in rustc style:
///
/// ```text
/// warning[wall-clock]: host wall-clock read: Instant::now
///   --> runtime/executor.rs:216:21
///    |
/// 216|         let start = Instant::now();
///    |
///    = help: route timing through crate::sim::SimClock (DESIGN.md S24)
/// ```
pub fn render_human(d: &Diagnostic) -> String {
    let badge = match d.suppressed {
        Suppressed::No => "error",
        Suppressed::Inline => "allowed(inline)",
        Suppressed::Baseline => "allowed(baseline)",
    };
    let line_no = d.line.to_string();
    let gutter = " ".repeat(line_no.len());
    let mut s = String::new();
    s.push_str(&format!("{badge}[{}]: {}\n", d.rule, d.message));
    s.push_str(&format!("{gutter}--> {}:{}:{}\n", d.file, d.line, d.col));
    s.push_str(&format!("{gutter} |\n"));
    s.push_str(&format!("{line_no}| {}\n", d.snippet.trim_end()));
    s.push_str(&format!("{gutter} |\n"));
    s.push_str(&format!("{gutter} = help: {}\n", d.help));
    s
}

/// Escape a string for embedding in a JSON document.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the full diagnostic set as a deterministic JSON document.
pub fn render_json(root: &str, rules: &[&str], diags: &[Diagnostic]) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"version\": 1,\n");
    s.push_str(&format!("  \"root\": \"{}\",\n", json_escape(root)));
    let rule_list: Vec<String> = rules.iter().map(|r| format!("\"{}\"", json_escape(r))).collect();
    s.push_str(&format!("  \"rules\": [{}],\n", rule_list.join(", ")));
    s.push_str("  \"diagnostics\": [");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let suppressed = match d.suppressed {
            Suppressed::No => "null".to_string(),
            Suppressed::Inline => "\"inline\"".to_string(),
            Suppressed::Baseline => "\"baseline\"".to_string(),
        };
        s.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"col\": {}, \
             \"message\": \"{}\", \"snippet\": \"{}\", \"help\": \"{}\", \"suppressed\": {}}}",
            json_escape(d.rule),
            json_escape(&d.file),
            d.line,
            d.col,
            json_escape(&d.message),
            json_escape(d.snippet.trim_end()),
            json_escape(d.help),
            suppressed
        ));
    }
    if !diags.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("],\n");
    let active = diags.iter().filter(|d| d.is_active()).count();
    s.push_str(&format!(
        "  \"summary\": {{\"total\": {}, \"active\": {}, \"suppressed\": {}}}\n",
        diags.len(),
        active,
        diags.len() - active
    ));
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Diagnostic {
        Diagnostic {
            rule: "wall-clock",
            file: "runtime/executor.rs".to_string(),
            line: 216,
            col: 21,
            message: "host wall-clock read: Instant::now".to_string(),
            snippet: "        let start = Instant::now();".to_string(),
            help: "route timing through crate::sim::SimClock (DESIGN.md S24)",
            suppressed: Suppressed::No,
        }
    }

    #[test]
    fn human_render_has_location_and_help() {
        let out = render_human(&sample());
        assert!(out.contains("error[wall-clock]"));
        assert!(out.contains("runtime/executor.rs:216:21"));
        assert!(out.contains("= help:"));
    }

    #[test]
    fn json_escapes_and_counts() {
        let mut d = sample();
        d.message = "quote \" and backslash \\".to_string();
        let out = render_json("rust/src", &["wall-clock"], &[d]);
        assert!(out.contains("quote \\\" and backslash \\\\"));
        assert!(out.contains("\"active\": 1"));
    }

    #[test]
    fn json_empty_set_is_valid() {
        let out = render_json("rust/src", &["unwrap"], &[]);
        assert!(out.contains("\"diagnostics\": []"));
        assert!(out.contains("\"total\": 0"));
    }
}
