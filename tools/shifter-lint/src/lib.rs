//! shifter-lint: domain-aware static analysis for the shifter-rs tree.
//!
//! Enforces the determinism and error-handling invariants of DESIGN.md S26
//! over `rust/src/**` — the properties the compiler and clippy cannot
//! express but the byte-exact report guarantee (S24/S25) depends on:
//! no host wall-clock, no unordered iteration feeding reports, no
//! NaN-unsafe float ordering, no bare `unwrap`/`expect` in library code,
//! no host threads outside the sim, no lock-poison unwraps, and no
//! ambient-entropy seeds.
//!
//! The crate is dependency-free (the CI environment is offline/vendored),
//! so analysis runs on a hand-rolled token scanner rather than `syn`; see
//! [`lexer`] for exactly what is and is not understood. Rules are
//! patterns over that stream ([`rules`]), existing debt lives in a
//! ratcheted baseline ([`baseline`]), and diagnostics render rustc-style
//! or as JSON ([`diag`]).

pub mod baseline;
pub mod diag;
pub mod lexer;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use baseline::Baseline;
use diag::Diagnostic;
use rules::Config;

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .collect::<io::Result<Vec<_>>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Path relative to `root`, `/`-separated regardless of host OS.
fn rel_slash(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint every `.rs` file under `root`. Diagnostics come back in canonical
/// (file, line, col, rule) order with inline suppressions already applied;
/// the baseline has NOT been applied yet (see [`Baseline::apply`]).
pub fn lint_root(root: &Path, cfg: &Config) -> io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut diags = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)?;
        let rel = rel_slash(root, path);
        diags.extend(rules::check(&rel, &src, cfg));
    }
    diag::sort_canonical(&mut diags);
    Ok(diags)
}

/// Outcome of a full lint run, post-baseline.
#[derive(Debug)]
pub struct RunResult {
    pub diagnostics: Vec<Diagnostic>,
    pub active: usize,
    pub suppressed: usize,
}

/// Lint `root` and apply `baseline`. The run is clean iff `active == 0`.
pub fn run(root: &Path, cfg: &Config, baseline: &Baseline) -> io::Result<RunResult> {
    let mut diagnostics = lint_root(root, cfg)?;
    baseline.apply(&mut diagnostics);
    let active = diagnostics.iter().filter(|d| d.is_active()).count();
    let suppressed = diagnostics.len() - active;
    Ok(RunResult {
        diagnostics,
        active,
        suppressed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criterion of ISSUE 9: the swept tree lints clean
    /// with the committed baseline. Running it as a unit test means
    /// `cargo test` fails the moment a violation lands, even before the
    /// dedicated CI `analysis` job runs.
    #[test]
    fn swept_tree_is_clean() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        let root = manifest.join("../../rust/src");
        let bl = Baseline::load(&manifest.join("baseline.toml")).expect("baseline parses");
        let result = run(&root, &Config::default_policy(), &bl).expect("lint runs");
        let live: Vec<String> = result
            .diagnostics
            .iter()
            .filter(|d| d.is_active())
            .map(|d| format!("{}:{} {} ({})", d.file, d.line, d.rule, d.message))
            .collect();
        assert!(
            live.is_empty(),
            "shifter-lint found {} live violation(s) in rust/src:\n{}",
            live.len(),
            live.join("\n")
        );
    }
}
