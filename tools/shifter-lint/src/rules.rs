//! The seven shifter-lint rules (DESIGN.md S26).
//!
//! Every rule is a token-pattern over the [`crate::lexer`] stream. Items
//! under a test attribute (`#[test]`, `#[cfg(test)]`, `#[tokio::test]`,
//! ...) are exempt: the invariants protect *library* determinism, and test
//! code legitimately unwraps and spawns threads.
//!
//! | rule                  | forbids                                               |
//! |-----------------------|-------------------------------------------------------|
//! | `wall-clock`          | `Instant::now`, `SystemTime::now`, `UNIX_EPOCH` reads |
//! | `unordered-collection`| `HashMap`/`HashSet` in library code                   |
//! | `float-ordering`      | `partial_cmp().unwrap()`, float `sort_by` closures    |
//! | `unwrap`              | `.unwrap()` / `.expect()` in non-test code            |
//! | `thread`              | `thread::spawn` / `thread::scope`                     |
//! | `lock-poison`         | `.lock().unwrap()` — use `util::sync::lock_unpoisoned`|
//! | `entropy-seed`        | `from_entropy`, `thread_rng`, `RandomState`, ...      |

use std::collections::BTreeMap;

use crate::diag::{Diagnostic, Suppressed};
use crate::lexer::{lex, LexOutput, Token, TokenKind};

/// Rule identifiers in canonical (sorted) order.
pub const RULE_IDS: [&str; 7] = [
    "entropy-seed",
    "float-ordering",
    "lock-poison",
    "thread",
    "unordered-collection",
    "unwrap",
    "wall-clock",
];

fn help_for(rule: &str) -> &'static str {
    match rule {
        "wall-clock" => {
            "route timing through crate::sim::SimClock / SimTime (DESIGN.md S24); \
             host clocks make reports non-reproducible"
        }
        "unordered-collection" => {
            "use BTreeMap/BTreeSet (or sort before iterating); HashMap iteration \
             order feeds reports and must be deterministic"
        }
        "float-ordering" => {
            "use f64::total_cmp for float ordering; partial_cmp panics on NaN and \
             sort_by(partial_cmp) is not a total order"
        }
        "unwrap" => {
            "propagate a typed error (?) or panic explicitly with a diagnostic \
             message; bare unwrap/expect hides the failure contract"
        }
        "thread" => {
            "host threads break virtual-time determinism; model concurrency on the \
             SimKernel (DESIGN.md S24) or add the module to the lint allowlist"
        }
        "lock-poison" => {
            "use crate::util::sync::lock_unpoisoned: a panicked writer must not \
             cascade into every later reader"
        }
        "entropy-seed" => {
            "seed PRNGs and hashers explicitly (SplitMix/fixed keys); ambient \
             entropy diverges across runs and hosts"
        }
        _ => "see DESIGN.md S26",
    }
}

/// Per-rule path allowlist: module path prefixes (relative to the lint
/// root, `/`-separated) where a rule does not apply.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub allow_paths: BTreeMap<String, Vec<String>>,
}

impl Config {
    /// The committed policy for `rust/src` (DESIGN.md S26). All allowlists
    /// are currently empty: the tree was swept clean when the lint landed,
    /// and new exemptions should be taken as inline `lint:allow` directives
    /// with a reason, or (transitionally) as baseline entries — not as
    /// whole-module waivers.
    pub fn default_policy() -> Config {
        let mut allow_paths = BTreeMap::new();
        for rule in RULE_IDS {
            allow_paths.insert(rule.to_string(), Vec::new());
        }
        Config { allow_paths }
    }

    fn allowed(&self, rule: &str, relpath: &str) -> bool {
        match self.allow_paths.get(rule) {
            Some(prefixes) => prefixes.iter().any(|p| relpath.starts_with(p.as_str())),
            None => false,
        }
    }
}

/// If `toks[idx]` starts an attribute `#[...]`, return (index past the
/// closing bracket, idents seen inside).
fn attr_tokens(toks: &[Token], idx: usize) -> Option<(usize, Vec<&str>)> {
    if toks.get(idx).map(|t| t.text.as_str()) != Some("#") {
        return None;
    }
    if toks.get(idx + 1).map(|t| t.text.as_str()) != Some("[") {
        return None;
    }
    let mut depth = 0i32;
    let mut idents = Vec::new();
    let mut j = idx + 1;
    while j < toks.len() {
        let t = &toks[j];
        match t.text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return Some((j + 1, idents));
                }
            }
            _ => {
                if t.kind == TokenKind::Ident {
                    idents.push(t.text.as_str());
                }
            }
        }
        j += 1;
    }
    Some((toks.len(), idents))
}

/// `#[test]`, `#[cfg(test)]`, `#[cfg(any(test, ...))]`, `#[tokio::test]`.
fn is_test_attr(idents: &[&str]) -> bool {
    idents.iter().any(|i| *i == "test")
}

/// Token-index ranges covered by a test attribute and therefore exempt.
fn exempt_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        let Some((end_attr, idents)) = attr_tokens(toks, i) else {
            i += 1;
            continue;
        };
        if !is_test_attr(&idents) {
            i = end_attr;
            continue;
        }
        // Skip any further attributes on the same item.
        let mut j = end_attr;
        while let Some((next, _)) = attr_tokens(toks, j) {
            j = next;
        }
        // The item ends at `;` at brace depth 0, or at the `}` matching the
        // first `{` opened.
        let mut depth = 0i32;
        let mut k = j;
        while k < toks.len() {
            match toks[k].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        k += 1;
                        break;
                    }
                }
                ";" if depth == 0 => {
                    k += 1;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        ranges.push((i, k));
        i = k;
    }
    ranges
}

fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= idx && idx < b)
}

const WALLCLOCK_TYPES: [&str; 2] = ["Instant", "SystemTime"];
const UNORDERED: [&str; 2] = ["HashMap", "HashSet"];
const ENTROPY: [&str; 5] = [
    "from_entropy",
    "thread_rng",
    "RandomState",
    "DefaultHasher",
    "getrandom",
];
const SORTS: [&str; 5] = [
    "sort_by",
    "sort_unstable_by",
    "min_by",
    "max_by",
    "binary_search_by",
];

/// Find the index of the `)` matching the `(` at `open` (which must point
/// at a `(` token); returns `toks.len()` if unbalanced.
fn matching_paren(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
        j += 1;
    }
    toks.len()
}

/// Run every rule over one file. `relpath` is `/`-separated and relative to
/// the lint root; `src` is the file contents.
pub fn check(relpath: &str, src: &str, cfg: &Config) -> Vec<Diagnostic> {
    let lexed: LexOutput = lex(src);
    let toks = &lexed.tokens;
    let lines: Vec<&str> = src.lines().collect();
    let snippet = |line: u32| -> String {
        lines
            .get(line.saturating_sub(1) as usize)
            .map(|s| s.to_string())
            .unwrap_or_default()
    };
    let ranges = exempt_ranges(toks);
    let txt = |j: usize| -> &str { toks.get(j).map(|t| t.text.as_str()).unwrap_or("") };
    let prev = |j: usize| -> &str {
        match j.checked_sub(1) {
            Some(p) => txt(p),
            None => "",
        }
    };

    let mut diags: Vec<Diagnostic> = Vec::new();
    let mut push = |rule: &'static str, tok: &Token, message: String| {
        diags.push(Diagnostic {
            rule,
            file: relpath.to_string(),
            line: tok.line,
            col: tok.col,
            message,
            snippet: snippet(tok.line),
            help: help_for(rule),
            suppressed: Suppressed::No,
        });
    };

    // First pass: lock-poison claims its unwrap/expect token so the same
    // site is not double-reported by the unwrap rule.
    let mut claimed: Vec<usize> = Vec::new();
    for (i, tok) in toks.iter().enumerate() {
        if tok.kind != TokenKind::Ident || tok.text != "lock" {
            continue;
        }
        if in_ranges(i, &ranges) {
            continue;
        }
        if txt(i + 1) == "("
            && txt(i + 2) == ")"
            && txt(i + 3) == "."
            && (txt(i + 4) == "unwrap" || txt(i + 4) == "expect")
        {
            claimed.push(i + 4);
            if !cfg.allowed("lock-poison", relpath) {
                push(
                    "lock-poison",
                    tok,
                    format!("mutex guard unwrapped on poison: .lock().{}()", txt(i + 4)),
                );
            }
        }
    }

    for (i, tok) in toks.iter().enumerate() {
        if tok.kind != TokenKind::Ident {
            continue;
        }
        if in_ranges(i, &ranges) {
            continue;
        }
        let text = tok.text.as_str();

        // wall-clock: Instant::now / SystemTime::now / SystemTime::UNIX_EPOCH
        if WALLCLOCK_TYPES.contains(&text)
            && txt(i + 1) == ":"
            && txt(i + 2) == ":"
            && (txt(i + 3) == "now" || txt(i + 3) == "UNIX_EPOCH")
            && !cfg.allowed("wall-clock", relpath)
        {
            push(
                "wall-clock",
                tok,
                format!("host wall-clock read: {text}::{}", txt(i + 3)),
            );
        }

        // unordered-collection: the type name anywhere in library code
        // (imports included — the import is the gateway). `HashMap!` would
        // be a macro of the same name, not the std type.
        if UNORDERED.contains(&text)
            && txt(i + 1) != "!"
            && !cfg.allowed("unordered-collection", relpath)
        {
            push(
                "unordered-collection",
                tok,
                format!("unordered collection in library code: {text}"),
            );
        }

        // float-ordering (a): .partial_cmp(...).unwrap() / .expect(...)
        if text == "partial_cmp" && prev(i) == "." && txt(i + 1) == "(" {
            let close = matching_paren(toks, i + 1);
            if txt(close + 1) == "."
                && (txt(close + 2) == "unwrap" || txt(close + 2) == "expect")
                && !cfg.allowed("float-ordering", relpath)
            {
                push(
                    "float-ordering",
                    tok,
                    format!("partial_cmp().{}() panics on NaN", txt(close + 2)),
                );
            }
        }

        // float-ordering (b): sort_by/min_by/... whose closure calls
        // partial_cmp.
        if SORTS.contains(&text) && prev(i) == "." && txt(i + 1) == "(" {
            let close = matching_paren(toks, i + 1);
            let uses_partial = toks[i + 1..close.min(toks.len())]
                .iter()
                .any(|t| t.kind == TokenKind::Ident && t.text == "partial_cmp");
            if uses_partial && !cfg.allowed("float-ordering", relpath) {
                push(
                    "float-ordering",
                    tok,
                    format!("{text} over partial_cmp is not a total order"),
                );
            }
        }

        // unwrap: .unwrap( / .expect( in non-test code, unless the site was
        // already reported as lock-poison.
        if (text == "unwrap" || text == "expect")
            && prev(i) == "."
            && txt(i + 1) == "("
            && !claimed.contains(&i)
            && !cfg.allowed("unwrap", relpath)
        {
            push("unwrap", tok, format!(".{text}() in library code"));
        }

        // thread: thread::spawn / thread::scope
        if text == "thread"
            && txt(i + 1) == ":"
            && txt(i + 2) == ":"
            && (txt(i + 3) == "spawn" || txt(i + 3) == "scope")
            && !cfg.allowed("thread", relpath)
        {
            push(
                "thread",
                tok,
                format!("host thread primitive: thread::{}", txt(i + 3)),
            );
        }

        // entropy-seed: ambient-entropy constructors
        if ENTROPY.contains(&text) && !cfg.allowed("entropy-seed", relpath) {
            push(
                "entropy-seed",
                tok,
                format!("nondeterministic seed source: {text}"),
            );
        }
    }

    // Apply inline `lint:allow` directives: a directive excuses matching
    // diagnostics on its own line and the line immediately below.
    for d in diags.iter_mut() {
        let excused = lexed.allows.iter().any(|a| {
            (a.line == d.line || a.line + 1 == d.line)
                && a.rules.iter().any(|r| r == d.rule || r == "all")
        });
        if excused {
            d.suppressed = Suppressed::Inline;
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str) -> Vec<Diagnostic> {
        check("some/module.rs", src, &Config::default_policy())
    }

    fn active_rules(src: &str) -> Vec<&'static str> {
        run(src)
            .into_iter()
            .filter(|d| d.is_active())
            .map(|d| d.rule)
            .collect()
    }

    #[test]
    fn test_items_are_exempt() {
        let src = "
            #[cfg(test)]
            mod tests {
                fn helper() { let x = opt.unwrap(); }
            }
            fn lib() { let y = opt.unwrap(); }
        ";
        let rules = active_rules(src);
        assert_eq!(rules, vec!["unwrap"]);
    }

    #[test]
    fn lock_poison_claims_the_unwrap() {
        let src = "fn f() { let g = m.lock().unwrap(); }";
        let rules = active_rules(src);
        assert_eq!(rules, vec!["lock-poison"]);
    }

    #[test]
    fn partial_cmp_definition_is_not_flagged() {
        let src = "
            impl PartialOrd for T {
                fn partial_cmp(&self, other: &T) -> Option<Ordering> { None }
            }
        ";
        assert!(active_rules(src).is_empty());
    }

    #[test]
    fn inline_allow_suppresses() {
        let src = "
            // lint:allow(unwrap): construction of a static table
            fn f() { x.unwrap(); }
        ";
        let diags = run(src);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].suppressed, Suppressed::Inline);
    }

    #[test]
    fn allowlisted_path_is_skipped() {
        let mut cfg = Config::default_policy();
        cfg.allow_paths
            .insert("wall-clock".to_string(), vec!["bench/".to_string()]);
        let src = "fn f() { let t = Instant::now(); }";
        let diags = check("bench/timer.rs", src, &cfg);
        assert!(diags.is_empty());
        let diags = check("launch/mod.rs", src, &cfg);
        assert_eq!(diags.len(), 1);
    }
}
