//! The suppression baseline: committed debt, ratcheted down and never up.
//!
//! `baseline.toml` holds `[[allow]]` entries keyed by `(rule, file)` with a
//! `count` of tolerated violations. Counts (not line numbers) make the
//! baseline robust to unrelated edits shifting code around. Semantics:
//!
//! * A run suppresses the first `count` diagnostics of `(rule, file)` in
//!   source order; anything beyond the count is reported live.
//! * `--update-baseline` only ever *lowers* counts (to the current live
//!   total) and drops entries that reach zero. It never adds entries or
//!   raises counts — new debt must be fixed or explicitly `lint:allow`ed.
//! * `--init-baseline` bootstraps the file from the current tree; it is a
//!   one-time escape hatch, not part of the normal workflow.
//!
//! The file format is a small TOML subset (tables-of-tables with string and
//! integer values) so the dependency-free tool can read and write it.

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::Path;

use crate::diag::{Diagnostic, Suppressed};

/// Allowed-violation counts keyed by `(rule, file)`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Baseline {
    pub entries: BTreeMap<(String, String), u32>,
}

fn parse_err(path: &Path, line_no: usize, msg: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("{}:{}: {}", path.display(), line_no, msg),
    )
}

impl Baseline {
    /// Load a baseline; a missing file is an empty baseline.
    pub fn load(path: &Path) -> io::Result<Baseline> {
        let text = match fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(Baseline::default()),
            Err(e) => return Err(e),
        };
        let mut entries = BTreeMap::new();
        let mut rule: Option<String> = None;
        let mut file: Option<String> = None;
        let mut count: Option<u32> = None;
        let mut in_entry = false;

        let mut flush = |rule: &mut Option<String>,
                         file: &mut Option<String>,
                         count: &mut Option<u32>,
                         entries: &mut BTreeMap<(String, String), u32>,
                         line_no: usize|
         -> io::Result<()> {
            match (rule.take(), file.take(), count.take()) {
                (Some(r), Some(f), Some(c)) => {
                    entries.insert((r, f), c);
                    Ok(())
                }
                (None, None, None) => Ok(()),
                _ => Err(parse_err(
                    path,
                    line_no,
                    "incomplete [[allow]] entry: need rule, file and count",
                )),
            }
        };

        for (idx, raw) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if line == "[[allow]]" {
                flush(&mut rule, &mut file, &mut count, &mut entries, line_no)?;
                in_entry = true;
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(parse_err(path, line_no, "expected `key = value`"));
            };
            if !in_entry {
                return Err(parse_err(path, line_no, "key outside [[allow]] entry"));
            }
            let key = key.trim();
            let value = value.trim();
            match key {
                "rule" | "file" => {
                    let unquoted = value
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| parse_err(path, line_no, "expected a quoted string"))?;
                    if key == "rule" {
                        rule = Some(unquoted.to_string());
                    } else {
                        file = Some(unquoted.to_string());
                    }
                }
                "count" => {
                    let parsed: u32 = value
                        .parse()
                        .map_err(|_| parse_err(path, line_no, "count must be an integer"))?;
                    count = Some(parsed);
                }
                other => {
                    return Err(parse_err(path, line_no, &format!("unknown key `{other}`")));
                }
            }
        }
        flush(&mut rule, &mut file, &mut count, &mut entries, text.lines().count())?;
        Ok(Baseline { entries })
    }

    /// Serialize deterministically (sorted by rule, then file).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("# shifter-lint suppression baseline (DESIGN.md S26).\n");
        s.push_str("# Counts only ever ratchet DOWN: `--update-baseline` lowers them as\n");
        s.push_str("# debt is paid off and never adds entries. New violations are fixed\n");
        s.push_str("# or carry an inline `lint:allow(rule): reason` directive.\n");
        for ((rule, file), count) in &self.entries {
            s.push('\n');
            s.push_str("[[allow]]\n");
            s.push_str(&format!("rule = \"{rule}\"\n"));
            s.push_str(&format!("file = \"{file}\"\n"));
            s.push_str(&format!("count = {count}\n"));
        }
        s
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        fs::write(path, self.render())
    }

    /// Mark the first `count` diagnostics of each `(rule, file)` group as
    /// baseline-suppressed. `diags` must already be in canonical order so
    /// "first" is stable. Inline-suppressed diagnostics don't consume
    /// baseline budget.
    pub fn apply(&self, diags: &mut [Diagnostic]) {
        let mut used: BTreeMap<(String, String), u32> = BTreeMap::new();
        for d in diags.iter_mut() {
            if d.suppressed != Suppressed::No {
                continue;
            }
            let key = (d.rule.to_string(), d.file.clone());
            let budget = self.entries.get(&key).copied().unwrap_or(0);
            let spent = used.entry(key).or_insert(0);
            if *spent < budget {
                *spent += 1;
                d.suppressed = Suppressed::Baseline;
            }
        }
    }

    /// Live violation counts per `(rule, file)` (inline-suppressed sites
    /// excluded — they are already individually justified).
    pub fn current_counts(diags: &[Diagnostic]) -> BTreeMap<(String, String), u32> {
        let mut counts: BTreeMap<(String, String), u32> = BTreeMap::new();
        for d in diags {
            if d.suppressed == Suppressed::Inline {
                continue;
            }
            *counts
                .entry((d.rule.to_string(), d.file.clone()))
                .or_insert(0) += 1;
        }
        counts
    }

    /// Ratchet: lower every entry to `min(existing, current)`, dropping
    /// entries that hit zero. Returns the number of entries changed.
    pub fn ratchet(&mut self, current: &BTreeMap<(String, String), u32>) -> usize {
        let mut changed = 0usize;
        let mut next = BTreeMap::new();
        for (key, &allowed) in &self.entries {
            let now = current.get(key).copied().unwrap_or(0);
            let new = allowed.min(now);
            if new != allowed {
                changed += 1;
            }
            if new > 0 {
                next.insert(key.clone(), new);
            }
        }
        self.entries = next;
        changed
    }

    /// Bootstrap from the current tree (`--init-baseline`).
    pub fn init(current: &BTreeMap<(String, String), u32>) -> Baseline {
        Baseline {
            entries: current
                .iter()
                .filter(|(_, &c)| c > 0)
                .map(|(k, &c)| (k.clone(), c))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(rule: &str, file: &str, count: u32) -> ((String, String), u32) {
        ((rule.to_string(), file.to_string()), count)
    }

    #[test]
    fn render_parse_round_trip() {
        let b = Baseline {
            entries: [entry("unwrap", "launch/mod.rs", 3), entry("thread", "sim/mod.rs", 1)]
                .into_iter()
                .collect(),
        };
        let dir = std::env::temp_dir().join(format!("shifter-lint-bl-{}", std::process::id()));
        fs::create_dir_all(&dir).expect("temp dir");
        let path = dir.join("baseline.toml");
        b.save(&path).expect("save");
        let loaded = Baseline::load(&path).expect("load");
        assert_eq!(b, loaded);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_empty() {
        let b = Baseline::load(Path::new("/nonexistent/shifter-lint/baseline.toml"))
            .expect("missing file is not an error");
        assert!(b.entries.is_empty());
    }

    #[test]
    fn ratchet_only_lowers() {
        let mut b = Baseline {
            entries: [entry("unwrap", "a.rs", 5), entry("unwrap", "b.rs", 2)]
                .into_iter()
                .collect(),
        };
        // a.rs improved to 1 live; b.rs regressed to 7 live.
        let current: BTreeMap<_, _> =
            [entry("unwrap", "a.rs", 1), entry("unwrap", "b.rs", 7)].into_iter().collect();
        let changed = b.ratchet(&current);
        assert_eq!(changed, 1);
        assert_eq!(b.entries.get(&("unwrap".into(), "a.rs".into())), Some(&1));
        // Regression does NOT raise the allowance.
        assert_eq!(b.entries.get(&("unwrap".into(), "b.rs".into())), Some(&2));
    }

    #[test]
    fn ratchet_drops_zeroed_entries() {
        let mut b = Baseline {
            entries: [entry("unwrap", "a.rs", 5)].into_iter().collect(),
        };
        let current = BTreeMap::new();
        b.ratchet(&current);
        assert!(b.entries.is_empty());
    }
}
